//! Disk plumbing for the external sorter: bulk little-endian codecs,
//! overlap primitives (prefetch + write-behind threads), spill-file
//! lifecycle guards, spill-segment integrity (per-block CRC-32 sidecar
//! + verified reader with bounded re-read recovery), and the bounded
//! producer/worker/sink pipeline that shards run formation across
//! cores.
//!
//! Everything here is format-agnostic bytes: the key-only engine
//! ([`super::extsort`]) and the key-value twin ([`super::kv`]) share
//! one prefetcher, one write-behind, and one verified reader by
//! choosing their record stride (4-byte keys vs 12-byte records) at
//! the decode/encode layer.

use crate::obs::{Hist, HistStats};
use crate::util::crc32::{crc32, crc32_finish, crc32_update, CRC32_INIT};
use crate::util::fault::{self, Site};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Stack size for I/O helper threads (prefetchers, write-behind,
/// pipeline workers). They run no deep recursion, and a partitioned
/// final merge may hold `partitions · fan-in` of them at once.
const IO_STACK: usize = 128 * 1024;

/// LE-encode `keys` into `bytes` (cleared first) as one bulk append —
/// `resize` + fixed-width `chunks_exact_mut` stores, not a per-key
/// `extend_from_slice` loop. This sits on the disk hot path of every
/// spill and output write.
pub fn encode_keys_into(keys: &[u32], bytes: &mut Vec<u8>) {
    bytes.clear();
    bytes.resize(keys.len() * 4, 0);
    for (dst, &k) in bytes.chunks_exact_mut(4).zip(keys) {
        dst.copy_from_slice(&k.to_le_bytes());
    }
}

/// Decode a whole buffer of LE `u32` keys, appending to `out`.
/// `bytes.len()` must be a multiple of 4.
pub fn decode_keys_into(bytes: &[u8], out: &mut Vec<u32>) {
    debug_assert_eq!(bytes.len() % 4, 0);
    out.reserve(bytes.len() / 4);
    out.extend(bytes.chunks_exact(4).map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]])));
}

/// LE-encode 12-byte `(u32 key, u64 payload)` records into `bytes`
/// (cleared first), bulk like [`encode_keys_into`].
pub fn encode_records_into(keys: &[u32], pays: &[u64], bytes: &mut Vec<u8>) {
    debug_assert_eq!(keys.len(), pays.len());
    bytes.clear();
    bytes.resize(keys.len() * 12, 0);
    for ((dst, &k), &p) in bytes.chunks_exact_mut(12).zip(keys).zip(pays) {
        dst[..4].copy_from_slice(&k.to_le_bytes());
        dst[4..].copy_from_slice(&p.to_le_bytes());
    }
}

/// Decode a whole buffer of 12-byte records, appending to the columns.
/// `bytes.len()` must be a multiple of 12.
pub fn decode_records_into(bytes: &[u8], keys: &mut Vec<u32>, pays: &mut Vec<u64>) {
    debug_assert_eq!(bytes.len() % 12, 0);
    keys.reserve(bytes.len() / 12);
    pays.reserve(bytes.len() / 12);
    for rec in bytes.chunks_exact(12) {
        keys.push(u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]));
        pays.push(u64::from_le_bytes([
            rec[4], rec[5], rec[6], rec[7], rec[8], rec[9], rec[10], rec[11],
        ]));
    }
}

/// Shared I/O accounting, cloned into every helper thread: nanoseconds
/// compute threads spent blocked on disk, per-phase latency histograms
/// (chunk sort, spill write, prefetch wait — the `loms sort --stats`
/// breakdown), plus the spill-integrity event counters (blocks that
/// failed their checksum, bounded re-read retries). Drained into
/// [`super::extsort::ExtSortStats`].
#[derive(Clone, Default)]
pub struct IoWait(Arc<WaitInner>);

#[derive(Default)]
struct WaitInner {
    nanos: AtomicU64,
    corrupt: AtomicU64,
    retries: AtomicU64,
    chunk_sort: Hist,
    spill_write: Hist,
    prefetch_wait: Hist,
}

/// Phase label for the per-phase histograms behind
/// `loms sort --stats true`.
#[derive(Clone, Copy, Debug)]
pub enum IoPhase {
    /// CPU time sorting one chunk into a run. Recorded in its
    /// histogram only — *not* charged to the blocked-on-disk total
    /// ([`IoWait::secs`]), because it is compute, not I/O.
    ChunkSort,
    /// Blocked handing a spill/output buffer to the disk.
    SpillWrite,
    /// Blocked on the prefetch thread for the next filled buffer.
    PrefetchWait,
}

impl IoWait {
    pub fn new() -> Self {
        Self::default()
    }

    fn hist(&self, phase: IoPhase) -> &Hist {
        match phase {
            IoPhase::ChunkSort => &self.0.chunk_sort,
            IoPhase::SpillWrite => &self.0.spill_write,
            IoPhase::PrefetchWait => &self.0.prefetch_wait,
        }
    }

    /// Run `f`, charging its wall time to the counter.
    pub fn timed<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.0.nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }

    /// Run `f`, recording its wall time in `phase`'s histogram. The
    /// I/O phases also charge the blocked-on-disk total;
    /// [`IoPhase::ChunkSort`] does not (see its doc).
    pub fn timed_phase<T>(&self, phase: IoPhase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let d = t0.elapsed();
        if !matches!(phase, IoPhase::ChunkSort) {
            self.0.nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        }
        self.hist(phase).record_duration(d);
        out
    }

    /// Snapshot one phase histogram.
    pub fn phase_stats(&self, phase: IoPhase) -> HistStats {
        self.hist(phase).snapshot()
    }

    /// Total accumulated wait in seconds.
    pub fn secs(&self) -> f64 {
        self.0.nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Record one spill block that failed its checksum.
    pub fn note_corrupt(&self) {
        self.0.corrupt.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one bounded re-read of a spill block.
    pub fn note_retry(&self) {
        self.0.retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn corrupt_detected(&self) -> u64 {
        self.0.corrupt.load(Ordering::Relaxed)
    }

    pub fn read_retries(&self) -> u64 {
        self.0.retries.load(Ordering::Relaxed)
    }
}

/// Unlinks every registered spill file when dropped — the error-path
/// (and panic-path) lifecycle for spill files. The owning sort
/// registers each spill file at creation and calls [`Self::remove_now`]
/// as files are consumed; on a clean finish nothing is left to unlink,
/// on any early exit the guard sweeps the stragglers.
#[derive(Clone, Default)]
pub struct SpillGuard(Arc<GuardInner>);

#[derive(Default)]
struct GuardInner(Mutex<Vec<PathBuf>>);

impl Drop for GuardInner {
    fn drop(&mut self) {
        for p in self.0.get_mut().unwrap_or_else(|e| e.into_inner()).drain(..) {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl SpillGuard {
    pub fn new() -> Self {
        Self::default()
    }

    /// Poison-tolerant lock: the guard must keep cleaning up even after
    /// a panic elsewhere — that is its whole job.
    fn paths(&self) -> std::sync::MutexGuard<'_, Vec<PathBuf>> {
        self.0 .0.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Track `path` for unlink-on-drop.
    pub fn register(&self, path: &Path) {
        self.paths().push(path.to_path_buf());
    }

    /// Unlink `path` now and stop tracking it (the consumed-segment /
    /// clean-finish path).
    pub fn remove_now(&self, path: &Path) {
        let _ = std::fs::remove_file(path);
        self.paths().retain(|p| p != path);
    }
}

/// Unlink a spill segment *and* its checksum sidecar, dropping both
/// from the guard. Safe when no sidecar exists (verification off).
pub(crate) fn remove_seg(guard: &SpillGuard, path: &Path) {
    guard.remove_now(path);
    guard.remove_now(&sidecar_path(path));
}

/// Typed failure of the external sort's spill layer. Carried inside
/// `anyhow::Error` chains (callers `downcast_ref::<ExtSortError>()`):
/// corruption and disk-full become diagnosable conditions instead of
/// panics, and the [`SpillGuard`] still sweeps partial segments on the
/// way out.
#[derive(Debug)]
pub enum ExtSortError {
    /// A spill block failed its checksum (or the segment/sidecar is
    /// structurally invalid) and one bounded re-read did not recover
    /// it. `offset` is the byte offset of the bad block in `run`.
    CorruptSpill { run: PathBuf, offset: u64 },
    /// An I/O error (ENOSPC, permissions, vanished file, ...) on a
    /// spill read or write.
    Spill(std::io::Error),
}

impl std::fmt::Display for ExtSortError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExtSortError::CorruptSpill { run, offset } => {
                write!(f, "corrupt spill block at byte {offset} of {}", run.display())
            }
            ExtSortError::Spill(e) => write!(f, "spill I/O failed: {e}"),
        }
    }
}

impl std::error::Error for ExtSortError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ExtSortError::CorruptSpill { .. } => None,
            ExtSortError::Spill(e) => Some(e),
        }
    }
}

/// Wrap a spill-path I/O error into a typed [`ExtSortError::Spill`]
/// with a human-readable context line.
pub(crate) fn spill_io(e: std::io::Error, what: &str, path: &Path) -> anyhow::Error {
    let msg = format!("{what} {}", path.display());
    anyhow::Error::new(ExtSortError::Spill(e)).context(msg)
}

// ---------------------------------------------------------------------------
// Spill-segment integrity: out-of-band per-block checksum sidecar.
//
// Spill *data* files stay raw little-endian records — the partition
// cutter and run addressing depend on byte-stable record offsets, so
// integrity metadata lives out of band in a `<segment>.crc` sidecar:
// one fixed-size entry per `SPILL_BLOCK_RECS`-record block, blocks
// aligned to absolute data-file offsets (the last block may be
// partial). A reader covering records [start, start+len) fetches the
// sidecar entries for exactly the blocks that range touches, reads
// block-aligned, verifies each block, and trims to the request.
// ---------------------------------------------------------------------------

/// Sidecar entry magic ("LSBK" on disk, little-endian).
pub const SPILL_MAGIC: u32 = 0x4B42_534C;
/// Sidecar format version.
pub const SPILL_VERSION: u8 = 1;
/// Records per checksum block. 16 Ki records = 64 KiB blocks for
/// 4-byte keys, 192 KiB for 12-byte KV records — big enough that the
/// CRC amortizes, small enough that a bounded re-read is cheap.
pub const SPILL_BLOCK_RECS: usize = 16_384;
/// Encoded size of one sidecar entry.
pub const SPILL_META_BYTES: usize = 12;

/// One decoded sidecar entry. Every encoded bit is covered by an exact
/// check somewhere: magic and version at decode, `stride` and
/// `rec_count` against values derived from the data-file size at
/// verify, `crc` against the recomputed payload checksum — so any
/// single-bit flip in an entry is caught deterministically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpillBlockMeta {
    /// Record stride in bytes (4 = keys, 12 = KV records).
    pub stride: u8,
    /// Records in this block (`SPILL_BLOCK_RECS` except a partial tail).
    pub rec_count: u16,
    /// CRC-32 over the block's raw payload bytes.
    pub crc: u32,
}

/// Append the 12-byte wire form of `meta` to `out`:
/// `magic u32 LE | version u8 | stride u8 | rec_count u16 LE | crc u32 LE`.
pub fn encode_block_meta(meta: &SpillBlockMeta, out: &mut Vec<u8>) {
    out.extend_from_slice(&SPILL_MAGIC.to_le_bytes());
    out.push(SPILL_VERSION);
    out.push(meta.stride);
    out.extend_from_slice(&meta.rec_count.to_le_bytes());
    out.extend_from_slice(&meta.crc.to_le_bytes());
}

/// Decode one sidecar entry, rejecting bad length, magic, or version.
pub fn decode_block_meta(bytes: &[u8]) -> std::result::Result<SpillBlockMeta, &'static str> {
    if bytes.len() != SPILL_META_BYTES {
        return Err("truncated spill block meta");
    }
    if u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) != SPILL_MAGIC {
        return Err("bad spill block magic");
    }
    if bytes[4] != SPILL_VERSION {
        return Err("unsupported spill block version");
    }
    Ok(SpillBlockMeta {
        stride: bytes[5],
        rec_count: u16::from_le_bytes([bytes[6], bytes[7]]),
        crc: u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]),
    })
}

/// Path of the checksum sidecar for a spill data file (`<data>.crc`).
pub fn sidecar_path(data: &Path) -> PathBuf {
    let mut s = data.as_os_str().to_os_string();
    s.push(".crc");
    PathBuf::from(s)
}

/// Writer-side rolling checksummer: fed every encoded buffer a spill
/// writer emits (in file order), it walks block boundaries, accumulates
/// a streaming CRC per block, and yields the encoded sidecar at segment
/// close. Pure compute — it never touches the disk itself.
pub(crate) struct SpillChecksum {
    stride: u8,
    block_bytes: usize,
    fill: usize,
    state: u32,
    entries: Vec<u8>,
}

impl SpillChecksum {
    pub(crate) fn new(stride: usize) -> SpillChecksum {
        debug_assert!(stride > 0 && stride <= u8::MAX as usize);
        SpillChecksum {
            stride: stride as u8,
            block_bytes: SPILL_BLOCK_RECS * stride,
            fill: 0,
            state: CRC32_INIT,
            entries: Vec::new(),
        }
    }

    /// Absorb the next `bytes` of the segment (must be fed in exact
    /// file order; callers feed each buffer once, before or after the
    /// physical write).
    pub(crate) fn update(&mut self, mut bytes: &[u8]) {
        while !bytes.is_empty() {
            let take = (self.block_bytes - self.fill).min(bytes.len());
            self.state = crc32_update(self.state, &bytes[..take]);
            self.fill += take;
            bytes = &bytes[take..];
            if self.fill == self.block_bytes {
                self.seal();
            }
        }
    }

    fn seal(&mut self) {
        let meta = SpillBlockMeta {
            stride: self.stride,
            rec_count: (self.fill / self.stride as usize) as u16,
            crc: crc32_finish(self.state),
        };
        encode_block_meta(&meta, &mut self.entries);
        self.fill = 0;
        self.state = CRC32_INIT;
    }

    /// Seal any partial tail block and return the encoded sidecar
    /// bytes, ready to be written to [`sidecar_path`].
    pub(crate) fn finish(mut self) -> Vec<u8> {
        if self.fill > 0 {
            self.seal();
        }
        self.entries
    }
}

/// Where the current block's bytes live inside a [`SpillReader`].
#[derive(Clone, Copy)]
enum Loc {
    /// In `scratch` (synchronous reads and all bounded re-reads).
    Scratch,
    /// In the prefetch buffer at this offset.
    Buf(usize),
}

enum SpillSrc {
    Sync(File),
    Prefetch { pf: FilePrefetch, buf: Vec<u8>, pos: usize },
}

/// Verified reader over records `[start, start+len)` of a checksummed
/// spill segment. Reads are block-aligned (rounding the range out to
/// checksum-block boundaries, trimming the delivered slices back to
/// the request); each block is verified against its sidecar entry.
/// Any failure — I/O error, short read, checksum mismatch, or an
/// injected fault — gets exactly one bounded recovery attempt: a
/// synchronous re-read of that block through a fresh file handle. If
/// the re-read verifies, the sort proceeds byte-identically (the event
/// is counted); if not, a typed [`ExtSortError`] surfaces.
pub(crate) struct SpillReader {
    path: PathBuf,
    stride: usize,
    block_bytes: u64,
    file_bytes: u64,
    start_byte: u64,
    end_byte: u64,
    blk_lo: u64,
    blk_hi: u64,
    next_blk: u64,
    metas: Vec<SpillBlockMeta>,
    src: SpillSrc,
    scratch: Vec<u8>,
    wait: IoWait,
}

impl SpillReader {
    /// `prefetch_recs == 0` selects synchronous reads; otherwise a
    /// [`FilePrefetch`] thread streams whole blocks ahead (the buffer
    /// is rounded up to a block multiple so blocks never straddle
    /// buffers).
    pub(crate) fn open(
        path: &Path,
        start_rec: u64,
        len_recs: u64,
        stride: usize,
        prefetch_recs: usize,
        wait: IoWait,
    ) -> Result<SpillReader> {
        let block_bytes = (SPILL_BLOCK_RECS * stride) as u64;
        let file_bytes = std::fs::metadata(path)
            .map_err(|e| spill_io(e, "stat of spill segment", path))?
            .len();
        let corrupt = |offset: u64| {
            anyhow::Error::new(ExtSortError::CorruptSpill { run: path.to_path_buf(), offset })
        };
        if file_bytes % stride as u64 != 0 {
            // A segment that is not a whole number of records was
            // truncated or overwritten on disk.
            return Err(corrupt(file_bytes).context("spill segment length is not record-aligned"));
        }
        let start_byte = start_rec * stride as u64;
        let end_byte = (start_rec + len_recs) * stride as u64;
        if end_byte > file_bytes {
            return Err(corrupt(file_bytes).context("spill segment shorter than its run index"));
        }
        let blk_lo = start_byte / block_bytes;
        let blk_hi = if len_recs == 0 { blk_lo } else { end_byte.div_ceil(block_bytes) };

        // Sidecar entries for exactly the blocks this range touches.
        // Sidecar problems are immediate typed errors (no retry): the
        // sidecar is tiny, written once, and read in one gulp.
        let side = sidecar_path(path);
        let mut metas = Vec::with_capacity((blk_hi - blk_lo) as usize);
        if blk_hi > blk_lo {
            let mut f = File::open(&side)
                .map_err(|e| spill_io(e, "opening spill checksum sidecar", &side))?;
            f.seek(SeekFrom::Start(blk_lo * SPILL_META_BYTES as u64))
                .map_err(|e| spill_io(e, "seeking spill checksum sidecar", &side))?;
            let mut raw = vec![0u8; (blk_hi - blk_lo) as usize * SPILL_META_BYTES];
            wait.timed(|| f.read_exact(&mut raw))
                .map_err(|e| spill_io(e, "reading spill checksum sidecar", &side))?;
            for (i, ent) in raw.chunks_exact(SPILL_META_BYTES).enumerate() {
                let m = decode_block_meta(ent)
                    .map_err(|why| corrupt((blk_lo + i as u64) * block_bytes).context(why))?;
                metas.push(m);
            }
        }

        let read_lo = blk_lo * block_bytes;
        let read_hi = (blk_hi * block_bytes).min(file_bytes);
        let src = if prefetch_recs == 0 || len_recs == 0 {
            let mut f =
                File::open(path).map_err(|e| spill_io(e, "opening spill segment", path))?;
            f.seek(SeekFrom::Start(read_lo))
                .map_err(|e| spill_io(e, "seeking spill segment", path))?;
            SpillSrc::Sync(f)
        } else {
            let want = (prefetch_recs * stride) as u64;
            let bufs = want.div_ceil(block_bytes).max(1);
            let pf = FilePrefetch::spawn(
                path,
                read_lo,
                read_hi - read_lo,
                (bufs * block_bytes) as usize,
                wait.clone(),
            )?;
            SpillSrc::Prefetch { pf, buf: Vec::new(), pos: 0 }
        };

        Ok(SpillReader {
            path: path.to_path_buf(),
            stride,
            block_bytes,
            file_bytes,
            start_byte,
            end_byte,
            blk_lo,
            blk_hi,
            next_blk: blk_lo,
            metas,
            src,
            scratch: Vec::new(),
            wait,
        })
    }

    /// The next verified block's in-range bytes (a whole number of
    /// records), or `None` once the range is exhausted.
    pub(crate) fn next_verified(&mut self) -> Result<Option<&[u8]>> {
        if self.next_blk >= self.blk_hi {
            return Ok(None);
        }
        let blk = self.next_blk;
        let blk_start = blk * self.block_bytes;
        let blk_len = self.block_bytes.min(self.file_bytes - blk_start) as usize;
        let meta = self.metas[(blk - self.blk_lo) as usize];

        // Attempt 0: bytes from the streaming source. Injected faults
        // land here — after the physical read, so stream cursors stay
        // consistent — and before verification, so injected corruption
        // is detected, never trusted.
        let mut checksum_failed = false;
        let attempt0 = match self.fetch_block(blk_len) {
            Ok(loc) => {
                let short = fault::fires(Site::SpillReadShort);
                if fault::fires(Site::SpillCorruptByte) {
                    self.flip_byte(loc);
                }
                if !short && self.verify(loc, blk_len, &meta) {
                    Some(loc)
                } else {
                    checksum_failed = !short;
                    None
                }
            }
            Err(_) => None,
        };

        let loc = match attempt0 {
            Some(loc) => loc,
            None => {
                if checksum_failed {
                    self.wait.note_corrupt();
                }
                // One bounded recovery: re-read this block through a
                // fresh handle at its absolute offset, verify again.
                self.wait.note_retry();
                self.reread(blk_start, blk_len)
                    .map_err(|e| spill_io(e, "re-reading spill block in", &self.path))?;
                if !self.verify(Loc::Scratch, blk_len, &meta) {
                    self.wait.note_corrupt();
                    return Err(anyhow::Error::new(ExtSortError::CorruptSpill {
                        run: self.path.clone(),
                        offset: blk_start,
                    }));
                }
                Loc::Scratch
            }
        };

        self.next_blk += 1;
        let lo = (self.start_byte.max(blk_start) - blk_start) as usize;
        let hi = (self.end_byte.min(blk_start + blk_len as u64) - blk_start) as usize;
        Ok(Some(&self.view(loc, blk_len)[lo..hi]))
    }

    /// Pull the next block's bytes off the streaming source, advancing
    /// its cursor exactly one block regardless of later verification.
    fn fetch_block(&mut self, blk_len: usize) -> std::io::Result<Loc> {
        match &mut self.src {
            SpillSrc::Sync(f) => {
                self.scratch.clear();
                self.scratch.resize(blk_len, 0);
                let scratch = &mut self.scratch;
                self.wait.timed(|| f.read_exact(scratch))?;
                Ok(Loc::Scratch)
            }
            SpillSrc::Prefetch { pf, buf, pos } => {
                if *pos == buf.len() {
                    match pf.next_buf().map_err(std::io::Error::other)? {
                        Some(b) => {
                            *buf = b;
                            *pos = 0;
                        }
                        None => return Err(std::io::ErrorKind::UnexpectedEof.into()),
                    }
                }
                if buf.len() - *pos < blk_len {
                    *pos = buf.len();
                    return Err(std::io::ErrorKind::UnexpectedEof.into());
                }
                let p = *pos;
                *pos += blk_len;
                Ok(Loc::Buf(p))
            }
        }
    }

    fn view(&self, loc: Loc, blk_len: usize) -> &[u8] {
        match (loc, &self.src) {
            (Loc::Scratch, _) => &self.scratch[..blk_len],
            (Loc::Buf(pos), SpillSrc::Prefetch { buf, .. }) => &buf[pos..pos + blk_len],
            // Unreachable by construction (sync fetches land in
            // scratch); an empty view simply fails verification.
            (Loc::Buf(_), SpillSrc::Sync(_)) => &[],
        }
    }

    fn verify(&self, loc: Loc, blk_len: usize, meta: &SpillBlockMeta) -> bool {
        let bytes = self.view(loc, blk_len);
        bytes.len() == blk_len
            && meta.stride as usize == self.stride
            && meta.rec_count as usize == blk_len / self.stride
            && meta.crc == crc32(bytes)
    }

    fn reread(&mut self, blk_start: u64, blk_len: usize) -> std::io::Result<()> {
        self.scratch.clear();
        self.scratch.resize(blk_len, 0);
        let mut f = File::open(&self.path)?;
        f.seek(SeekFrom::Start(blk_start))?;
        let scratch = &mut self.scratch;
        self.wait.timed(|| f.read_exact(scratch))
    }
}

/// Double-buffered read-ahead over one byte region of a file: a reader
/// thread fills buffer B while the consumer drains buffer A (channel
/// capacity 1 ⇒ at most two buffers in flight). Reads are sequential
/// after one seek, in `buf_bytes` chunks — callers pick a chunk size
/// that is a multiple of their record stride so records never straddle
/// buffers.
pub struct FilePrefetch {
    rx: Option<Receiver<std::io::Result<Vec<u8>>>>,
    handle: Option<JoinHandle<()>>,
    wait: IoWait,
}

impl FilePrefetch {
    pub fn spawn(
        path: &Path,
        start_byte: u64,
        len_bytes: u64,
        buf_bytes: usize,
        wait: IoWait,
    ) -> Result<FilePrefetch> {
        debug_assert!(buf_bytes > 0);
        let mut file =
            File::open(path).with_context(|| format!("opening run file {}", path.display()))?;
        file.seek(SeekFrom::Start(start_byte))
            .with_context(|| format!("seeking run at byte {start_byte} in {}", path.display()))?;
        let (tx, rx) = mpsc::sync_channel::<std::io::Result<Vec<u8>>>(1);
        let handle = std::thread::Builder::new()
            .name("loms-prefetch".into())
            .stack_size(IO_STACK)
            .spawn(move || {
                let mut remaining = len_bytes;
                while remaining > 0 {
                    let n = (buf_bytes as u64).min(remaining) as usize;
                    let mut buf = vec![0u8; n];
                    let res = file.read_exact(&mut buf).map(|()| buf);
                    let failed = res.is_err();
                    if tx.send(res).is_err() || failed {
                        return; // consumer gone, or error delivered
                    }
                    remaining -= n as u64;
                }
            })
            .context("spawning prefetch thread")?;
        Ok(FilePrefetch { rx: Some(rx), handle: Some(handle), wait })
    }

    /// Next filled buffer, `None` once the region is exhausted. Blocks
    /// only when the reader is behind (charged to the wait counter).
    pub fn next_buf(&mut self) -> Result<Option<Vec<u8>>> {
        let Some(rx) = &self.rx else { return Ok(None) };
        match self.wait.timed_phase(IoPhase::PrefetchWait, || rx.recv()) {
            Ok(Ok(buf)) => Ok(Some(buf)),
            Ok(Err(e)) => {
                self.rx = None;
                Err(e).context("prefetching spill run")
            }
            Err(_) => {
                // Sender exited: region fully delivered.
                self.rx = None;
                Ok(None)
            }
        }
    }
}

impl Drop for FilePrefetch {
    fn drop(&mut self) {
        // Closing the channel unblocks a sender mid-`send`; then join so
        // no reader outlives its file region.
        self.rx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Write-behind for one already-positioned file handle: the compute
/// thread hands off encoded buffers and keeps merging while a writer
/// thread drains them (channel capacity 2). Buffers recycle back to the
/// submitter to keep allocation off the steady state.
pub struct WriteBehind {
    tx: Option<SyncSender<Vec<u8>>>,
    recycle: Receiver<Vec<u8>>,
    handle: Option<JoinHandle<std::io::Result<()>>>,
    wait: IoWait,
}

impl WriteBehind {
    /// `file` should already be seeked to where writing starts; writes
    /// proceed sequentially from there. Plain `io::Result` throughout
    /// so spill-path callers can wrap failures into
    /// [`ExtSortError::Spill`] and output-path callers can add their
    /// own context.
    pub fn spawn(mut file: File, wait: IoWait) -> std::io::Result<WriteBehind> {
        let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(2);
        let (rtx, recycle) = mpsc::sync_channel::<Vec<u8>>(4);
        let handle = std::thread::Builder::new()
            .name("loms-writebehind".into())
            .stack_size(IO_STACK)
            .spawn(move || -> std::io::Result<()> {
                for buf in rx {
                    file.write_all(&buf)?;
                    let _ = rtx.try_send(buf); // recycle if there's room
                }
                file.flush()
            })?;
        Ok(WriteBehind { tx: Some(tx), recycle, handle: Some(handle), wait })
    }

    /// A cleared buffer to encode into — recycled when available.
    pub fn buffer(&self) -> Vec<u8> {
        match self.recycle.try_recv() {
            Ok(mut b) => {
                b.clear();
                b
            }
            Err(TryRecvError::Empty | TryRecvError::Disconnected) => Vec::new(),
        }
    }

    /// Queue `buf` for writing; blocks (charged to the wait counter)
    /// when two buffers are already in flight. A dead writer thread
    /// surfaces its I/O error here.
    pub fn submit(&mut self, buf: Vec<u8>) -> std::io::Result<()> {
        let Some(tx) = self.tx.as_ref() else {
            return Err(std::io::Error::other("write-behind used after finish"));
        };
        if self.wait.timed_phase(IoPhase::SpillWrite, || tx.send(buf)).is_err() {
            // Writer exited early: it can only have done so on error.
            self.join()?;
            return Err(std::io::Error::other("write-behind thread exited before finish"));
        }
        Ok(())
    }

    fn join(&mut self) -> std::io::Result<()> {
        self.tx = None;
        match self.handle.take() {
            Some(h) => match h.join() {
                Ok(res) => res,
                Err(_) => Err(std::io::Error::other("write-behind thread panicked")),
            },
            None => Ok(()),
        }
    }

    /// Drain the queue, flush, and surface any pending write error.
    pub fn finish(mut self) -> std::io::Result<()> {
        self.wait.clone().timed(|| self.join())
    }
}

impl Drop for WriteBehind {
    fn drop(&mut self) {
        let _ = self.join();
    }
}

/// Bounded producer / worker-pool / ordered-sink pipeline — phase-1 run
/// formation sharded across cores.
///
/// The calling thread runs `produce` (reading input chunks in order);
/// `threads` workers apply `work` (the CPU-bound per-run sort); a
/// dedicated sink thread applies `consume` in **production order**
/// (reordering out-of-order worker completions through a small map), so
/// spill writes land on disk exactly as the serial path would write
/// them. Channels are bounded (`2·threads` each way), capping resident
/// chunks at O(threads · run_len) however large the input.
///
/// The sink value is moved into the sink thread and handed back on
/// success; any producer or sink error tears the pipeline down (channel
/// closure unblocks every side) and is propagated.
pub(crate) fn pipeline<C, R, W>(
    threads: usize,
    mut produce: impl FnMut() -> Result<Option<C>>,
    work: impl Fn(C) -> R + Sync,
    sink: W,
    mut consume: impl FnMut(&mut W, R) -> Result<()> + Send,
) -> Result<W>
where
    C: Send,
    R: Send,
    W: Send,
{
    debug_assert!(threads >= 1);
    std::thread::scope(|s| {
        let (work_tx, work_rx) = mpsc::sync_channel::<(u64, C)>(2 * threads);
        let work_rx = Mutex::new(work_rx);
        let (done_tx, done_rx) = mpsc::sync_channel::<(u64, R)>(2 * threads);
        let work = &work;
        let work_rx = &work_rx;
        for _ in 0..threads {
            let done_tx = done_tx.clone();
            std::thread::Builder::new()
                .name("loms-runsort".into())
                .spawn_scoped(s, move || loop {
                    // Hold the lock only to take the next chunk. A
                    // poisoned lock means a sibling panicked — exit
                    // and let the pipeline tear down.
                    let Ok(guard) = work_rx.lock() else { return };
                    let msg = guard.recv();
                    drop(guard);
                    let Ok((seq, c)) = msg else { return };
                    if done_tx.send((seq, work(c))).is_err() {
                        return; // sink gone (error path)
                    }
                })
                .context("spawning run-sort worker")?;
        }
        drop(done_tx);
        let sink_handle = s.spawn(move || -> Result<W> {
            let mut sink = sink;
            let mut next = 0u64;
            let mut pending: BTreeMap<u64, R> = BTreeMap::new();
            for (seq, r) in done_rx {
                pending.insert(seq, r);
                while let Some(r) = pending.remove(&next) {
                    consume(&mut sink, r)?;
                    next += 1;
                }
            }
            anyhow::ensure!(pending.is_empty(), "run pipeline lost sorted chunks");
            Ok(sink)
        });
        // Produce on the calling thread; a failed send means the sink
        // (or every worker) exited early — stop and let join report it.
        let mut produce_err = None;
        let mut seq = 0u64;
        loop {
            match produce() {
                Ok(Some(c)) => {
                    if work_tx.send((seq, c)).is_err() {
                        break;
                    }
                    seq += 1;
                }
                Ok(None) => break,
                Err(e) => {
                    produce_err = Some(e);
                    break;
                }
            }
        }
        drop(work_tx); // workers drain and exit; then the sink's queue closes
        let sink_res = match sink_handle.join() {
            Ok(res) => res,
            Err(_) => Err(anyhow::anyhow!("run pipeline sink thread panicked")),
        };
        match produce_err {
            Some(e) => Err(e),
            None => sink_res,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_seg(name: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("loms-io-{}-{name}-{n}.u32", std::process::id()))
    }

    /// Write a checksummed segment of `keys`, returning its data path.
    fn write_seg(name: &str, keys: &[u32]) -> PathBuf {
        let path = tmp_seg(name);
        let mut bytes = Vec::new();
        encode_keys_into(keys, &mut bytes);
        let mut sum = SpillChecksum::new(4);
        sum.update(&bytes);
        std::fs::write(&path, &bytes).unwrap();
        std::fs::write(sidecar_path(&path), sum.finish()).unwrap();
        path
    }

    fn read_all(path: &Path, start: u64, len: u64, prefetch: usize) -> Result<Vec<u32>> {
        let mut rd = SpillReader::open(path, start, len, 4, prefetch, IoWait::new())?;
        let mut out = Vec::new();
        while let Some(b) = rd.next_verified()? {
            decode_keys_into(b, &mut out);
        }
        Ok(out)
    }

    fn cleanup(path: &Path) {
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(sidecar_path(path));
    }

    #[test]
    fn verified_round_trip_sync_and_prefetch() {
        // Multi-block segment with a partial tail block.
        let keys: Vec<u32> = (0..(SPILL_BLOCK_RECS as u32 * 2 + 1357)).collect();
        let path = write_seg("round", &keys);
        for prefetch in [0usize, 1 << 14, 1 << 18] {
            assert_eq!(read_all(&path, 0, keys.len() as u64, prefetch).unwrap(), keys);
            // Sub-range crossing a block boundary, misaligned both ends.
            let (s, l) = (SPILL_BLOCK_RECS as u64 - 7, 4096u64);
            assert_eq!(
                read_all(&path, s, l, prefetch).unwrap(),
                keys[s as usize..(s + l) as usize]
            );
        }
        assert!(read_all(&path, 3, 0, 1024).unwrap().is_empty());
        cleanup(&path);
    }

    #[test]
    fn flipped_payload_byte_is_detected() {
        let keys: Vec<u32> = (0..40_000u32).collect();
        let path = write_seg("flip", &keys);
        let mut raw = std::fs::read(&path).unwrap();
        raw[5] ^= 0x10;
        std::fs::write(&path, &raw).unwrap();
        let err = read_all(&path, 0, keys.len() as u64, 0).unwrap_err();
        match err.downcast_ref::<ExtSortError>() {
            Some(ExtSortError::CorruptSpill { offset, .. }) => assert_eq!(*offset, 0),
            other => panic!("expected CorruptSpill, got {other:?}"),
        }
        cleanup(&path);
    }

    #[test]
    fn every_flipped_sidecar_byte_is_detected() {
        let keys: Vec<u32> = (0..1000u32).collect();
        let path = write_seg("side", &keys);
        let side = sidecar_path(&path);
        for byte in 0..SPILL_META_BYTES {
            let mut raw = std::fs::read(&side).unwrap();
            raw[byte] ^= 1;
            std::fs::write(&side, &raw).unwrap();
            assert!(
                read_all(&path, 0, keys.len() as u64, 0).is_err(),
                "flip in sidecar byte {byte} undetected"
            );
            raw[byte] ^= 1;
            std::fs::write(&side, &raw).unwrap();
        }
        assert_eq!(read_all(&path, 0, keys.len() as u64, 0).unwrap(), keys);
        cleanup(&path);
    }

    #[test]
    fn truncated_segment_is_detected() {
        let keys: Vec<u32> = (0..1000u32).collect();
        let path = write_seg("trunc", &keys);
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(999 * 4).unwrap();
        drop(f);
        assert!(read_all(&path, 0, keys.len() as u64, 0).is_err());
        cleanup(&path);
    }
}
