//! Disk plumbing for the external sorter: bulk little-endian codecs,
//! overlap primitives (prefetch + write-behind threads), spill-file
//! lifecycle guards, and the bounded producer/worker/sink pipeline that
//! shards run formation across cores.
//!
//! Everything here is format-agnostic bytes: the key-only engine
//! ([`super::extsort`]) and the key-value twin ([`super::kv`]) share
//! one prefetcher and one write-behind by choosing their record stride
//! (4-byte keys vs 12-byte records) at the decode/encode layer.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Stack size for I/O helper threads (prefetchers, write-behind,
/// pipeline workers). They run no deep recursion, and a partitioned
/// final merge may hold `partitions · fan-in` of them at once.
const IO_STACK: usize = 128 * 1024;

/// LE-encode `keys` into `bytes` (cleared first) as one bulk append —
/// `resize` + fixed-width `chunks_exact_mut` stores, not a per-key
/// `extend_from_slice` loop. This sits on the disk hot path of every
/// spill and output write.
pub fn encode_keys_into(keys: &[u32], bytes: &mut Vec<u8>) {
    bytes.clear();
    bytes.resize(keys.len() * 4, 0);
    for (dst, &k) in bytes.chunks_exact_mut(4).zip(keys) {
        dst.copy_from_slice(&k.to_le_bytes());
    }
}

/// Decode a whole buffer of LE `u32` keys, appending to `out`.
/// `bytes.len()` must be a multiple of 4.
pub fn decode_keys_into(bytes: &[u8], out: &mut Vec<u32>) {
    debug_assert_eq!(bytes.len() % 4, 0);
    out.reserve(bytes.len() / 4);
    out.extend(bytes.chunks_exact(4).map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]])));
}

/// LE-encode 12-byte `(u32 key, u64 payload)` records into `bytes`
/// (cleared first), bulk like [`encode_keys_into`].
pub fn encode_records_into(keys: &[u32], pays: &[u64], bytes: &mut Vec<u8>) {
    debug_assert_eq!(keys.len(), pays.len());
    bytes.clear();
    bytes.resize(keys.len() * 12, 0);
    for ((dst, &k), &p) in bytes.chunks_exact_mut(12).zip(keys).zip(pays) {
        dst[..4].copy_from_slice(&k.to_le_bytes());
        dst[4..].copy_from_slice(&p.to_le_bytes());
    }
}

/// Decode a whole buffer of 12-byte records, appending to the columns.
/// `bytes.len()` must be a multiple of 12.
pub fn decode_records_into(bytes: &[u8], keys: &mut Vec<u32>, pays: &mut Vec<u64>) {
    debug_assert_eq!(bytes.len() % 12, 0);
    keys.reserve(bytes.len() / 12);
    pays.reserve(bytes.len() / 12);
    for rec in bytes.chunks_exact(12) {
        keys.push(u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]));
        pays.push(u64::from_le_bytes([
            rec[4], rec[5], rec[6], rec[7], rec[8], rec[9], rec[10], rec[11],
        ]));
    }
}

/// Shared I/O-wait accounting: nanoseconds compute threads spent
/// blocked on disk — synchronous reads/writes plus stalls waiting for a
/// prefetcher or the write-behind thread. Cloned into every helper;
/// drained into [`super::extsort::ExtSortStats::io_wait_secs`].
#[derive(Clone, Default)]
pub struct IoWait(Arc<AtomicU64>);

impl IoWait {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, charging its wall time to the counter.
    pub fn timed<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.0.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }

    /// Total accumulated wait in seconds.
    pub fn secs(&self) -> f64 {
        self.0.load(Ordering::Relaxed) as f64 / 1e9
    }
}

/// Unlinks every registered spill file when dropped — the error-path
/// (and panic-path) lifecycle for spill files. The owning sort
/// registers each spill file at creation and calls [`Self::remove_now`]
/// as files are consumed; on a clean finish nothing is left to unlink,
/// on any early exit the guard sweeps the stragglers.
#[derive(Clone, Default)]
pub struct SpillGuard(Arc<GuardInner>);

#[derive(Default)]
struct GuardInner(Mutex<Vec<PathBuf>>);

impl Drop for GuardInner {
    fn drop(&mut self) {
        for p in self.0.get_mut().unwrap_or_else(|e| e.into_inner()).drain(..) {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl SpillGuard {
    pub fn new() -> Self {
        Self::default()
    }

    /// Track `path` for unlink-on-drop.
    pub fn register(&self, path: &Path) {
        self.0 .0.lock().unwrap().push(path.to_path_buf());
    }

    /// Unlink `path` now and stop tracking it (the consumed-segment /
    /// clean-finish path).
    pub fn remove_now(&self, path: &Path) {
        let _ = std::fs::remove_file(path);
        self.0 .0.lock().unwrap().retain(|p| p != path);
    }
}

/// Double-buffered read-ahead over one byte region of a file: a reader
/// thread fills buffer B while the consumer drains buffer A (channel
/// capacity 1 ⇒ at most two buffers in flight). Reads are sequential
/// after one seek, in `buf_bytes` chunks — callers pick a chunk size
/// that is a multiple of their record stride so records never straddle
/// buffers.
pub struct FilePrefetch {
    rx: Option<Receiver<std::io::Result<Vec<u8>>>>,
    handle: Option<JoinHandle<()>>,
    wait: IoWait,
}

impl FilePrefetch {
    pub fn spawn(
        path: &Path,
        start_byte: u64,
        len_bytes: u64,
        buf_bytes: usize,
        wait: IoWait,
    ) -> Result<FilePrefetch> {
        debug_assert!(buf_bytes > 0);
        let mut file =
            File::open(path).with_context(|| format!("opening run file {}", path.display()))?;
        file.seek(SeekFrom::Start(start_byte))
            .with_context(|| format!("seeking run at byte {start_byte} in {}", path.display()))?;
        let (tx, rx) = mpsc::sync_channel::<std::io::Result<Vec<u8>>>(1);
        let handle = std::thread::Builder::new()
            .name("loms-prefetch".into())
            .stack_size(IO_STACK)
            .spawn(move || {
                let mut remaining = len_bytes;
                while remaining > 0 {
                    let n = (buf_bytes as u64).min(remaining) as usize;
                    let mut buf = vec![0u8; n];
                    let res = file.read_exact(&mut buf).map(|()| buf);
                    let failed = res.is_err();
                    if tx.send(res).is_err() || failed {
                        return; // consumer gone, or error delivered
                    }
                    remaining -= n as u64;
                }
            })
            .context("spawning prefetch thread")?;
        Ok(FilePrefetch { rx: Some(rx), handle: Some(handle), wait })
    }

    /// Next filled buffer, `None` once the region is exhausted. Blocks
    /// only when the reader is behind (charged to the wait counter).
    pub fn next_buf(&mut self) -> Result<Option<Vec<u8>>> {
        let Some(rx) = &self.rx else { return Ok(None) };
        match self.wait.timed(|| rx.recv()) {
            Ok(Ok(buf)) => Ok(Some(buf)),
            Ok(Err(e)) => {
                self.rx = None;
                Err(e).context("prefetching spill run")
            }
            Err(_) => {
                // Sender exited: region fully delivered.
                self.rx = None;
                Ok(None)
            }
        }
    }
}

impl Drop for FilePrefetch {
    fn drop(&mut self) {
        // Closing the channel unblocks a sender mid-`send`; then join so
        // no reader outlives its file region.
        self.rx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Write-behind for one already-positioned file handle: the compute
/// thread hands off encoded buffers and keeps merging while a writer
/// thread drains them (channel capacity 2). Buffers recycle back to the
/// submitter to keep allocation off the steady state.
pub struct WriteBehind {
    tx: Option<SyncSender<Vec<u8>>>,
    recycle: Receiver<Vec<u8>>,
    handle: Option<JoinHandle<std::io::Result<()>>>,
    wait: IoWait,
}

impl WriteBehind {
    /// `file` should already be seeked to where writing starts; writes
    /// proceed sequentially from there.
    pub fn spawn(mut file: File, wait: IoWait) -> Result<WriteBehind> {
        let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(2);
        let (rtx, recycle) = mpsc::sync_channel::<Vec<u8>>(4);
        let handle = std::thread::Builder::new()
            .name("loms-writebehind".into())
            .stack_size(IO_STACK)
            .spawn(move || -> std::io::Result<()> {
                for buf in rx {
                    file.write_all(&buf)?;
                    let _ = rtx.try_send(buf); // recycle if there's room
                }
                file.flush()
            })
            .context("spawning write-behind thread")?;
        Ok(WriteBehind { tx: Some(tx), recycle, handle: Some(handle), wait })
    }

    /// A cleared buffer to encode into — recycled when available.
    pub fn buffer(&self) -> Vec<u8> {
        match self.recycle.try_recv() {
            Ok(mut b) => {
                b.clear();
                b
            }
            Err(TryRecvError::Empty | TryRecvError::Disconnected) => Vec::new(),
        }
    }

    /// Queue `buf` for writing; blocks (charged to the wait counter)
    /// when two buffers are already in flight. A dead writer thread
    /// surfaces its I/O error here.
    pub fn submit(&mut self, buf: Vec<u8>) -> Result<()> {
        let tx = self.tx.as_ref().expect("submit after finish");
        if self.wait.timed(|| tx.send(buf)).is_err() {
            // Writer exited early: it can only have done so on error.
            self.join().context("write-behind failed")?;
            anyhow::bail!("write-behind thread exited before finish");
        }
        Ok(())
    }

    fn join(&mut self) -> Result<()> {
        self.tx = None;
        match self.handle.take() {
            Some(h) => match h.join() {
                Ok(res) => res.context("writing sorted output"),
                Err(_) => anyhow::bail!("write-behind thread panicked"),
            },
            None => Ok(()),
        }
    }

    /// Drain the queue, flush, and surface any pending write error.
    pub fn finish(mut self) -> Result<()> {
        self.wait.clone().timed(|| self.join())
    }
}

impl Drop for WriteBehind {
    fn drop(&mut self) {
        let _ = self.join();
    }
}

/// Bounded producer / worker-pool / ordered-sink pipeline — phase-1 run
/// formation sharded across cores.
///
/// The calling thread runs `produce` (reading input chunks in order);
/// `threads` workers apply `work` (the CPU-bound per-run sort); a
/// dedicated sink thread applies `consume` in **production order**
/// (reordering out-of-order worker completions through a small map), so
/// spill writes land on disk exactly as the serial path would write
/// them. Channels are bounded (`2·threads` each way), capping resident
/// chunks at O(threads · run_len) however large the input.
///
/// The sink value is moved into the sink thread and handed back on
/// success; any producer or sink error tears the pipeline down (channel
/// closure unblocks every side) and is propagated.
pub(crate) fn pipeline<C, R, W>(
    threads: usize,
    mut produce: impl FnMut() -> Result<Option<C>>,
    work: impl Fn(C) -> R + Sync,
    sink: W,
    mut consume: impl FnMut(&mut W, R) -> Result<()> + Send,
) -> Result<W>
where
    C: Send,
    R: Send,
    W: Send,
{
    debug_assert!(threads >= 1);
    std::thread::scope(|s| {
        let (work_tx, work_rx) = mpsc::sync_channel::<(u64, C)>(2 * threads);
        let work_rx = Mutex::new(work_rx);
        let (done_tx, done_rx) = mpsc::sync_channel::<(u64, R)>(2 * threads);
        let work = &work;
        let work_rx = &work_rx;
        for _ in 0..threads {
            let done_tx = done_tx.clone();
            std::thread::Builder::new()
                .name("loms-runsort".into())
                .spawn_scoped(s, move || loop {
                    // Hold the lock only to take the next chunk.
                    let msg = work_rx.lock().unwrap().recv();
                    let Ok((seq, c)) = msg else { return };
                    if done_tx.send((seq, work(c))).is_err() {
                        return; // sink gone (error path)
                    }
                })
                .expect("spawning run-sort worker");
        }
        drop(done_tx);
        let sink_handle = s.spawn(move || -> Result<W> {
            let mut sink = sink;
            let mut next = 0u64;
            let mut pending: BTreeMap<u64, R> = BTreeMap::new();
            for (seq, r) in done_rx {
                pending.insert(seq, r);
                while let Some(r) = pending.remove(&next) {
                    consume(&mut sink, r)?;
                    next += 1;
                }
            }
            anyhow::ensure!(pending.is_empty(), "run pipeline lost sorted chunks");
            Ok(sink)
        });
        // Produce on the calling thread; a failed send means the sink
        // (or every worker) exited early — stop and let join report it.
        let mut produce_err = None;
        let mut seq = 0u64;
        loop {
            match produce() {
                Ok(Some(c)) => {
                    if work_tx.send((seq, c)).is_err() {
                        break;
                    }
                    seq += 1;
                }
                Ok(None) => break,
                Err(e) => {
                    produce_err = Some(e);
                    break;
                }
            }
        }
        drop(work_tx); // workers drain and exit; then the sink's queue closes
        let sink_res = match sink_handle.join() {
            Ok(res) => res,
            Err(_) => Err(anyhow::anyhow!("run pipeline sink thread panicked")),
        };
        match produce_err {
            Some(e) => Err(e),
            None => sink_res,
        }
    })
}
