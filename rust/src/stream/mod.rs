//! Streaming merge engine: bounded-memory k-way merging of unbounded
//! sorted streams through the compiled LOMS tile kernels.
//!
//! The paper's devices are fixed-width block mergers; their classic
//! deployment (§II) is as the kernel inside a larger sorter. This
//! subsystem is that deployment in software, the way FLiMS
//! (Papaphilippou et al., arXiv:2112.05607) turns a fixed R+R merger
//! into a streaming 2-way merger and hardware merge trees compose fixed
//! mergers into k-way pipelines (arXiv:2310.07903):
//!
//! * [`source`] — the [`SortedStream`] trait and adapters (slices,
//!   owned runs, ascending iterators, file-of-runs spill windows).
//! * [`merge2`] — the FLiMS-style block merger: R-key head buffers, one
//!   `loms2` R+R kernel pass per step, emit the low cone / retain the
//!   high cone, refill from the consumed side. Fill is tracked by
//!   count, never by sentinel, so the full `u32` domain is legal.
//! * [`tree`] — [`MergeTree`]: a binary tree of block mergers with
//!   bounded inter-node FIFOs; every scheduling round batches all ready
//!   nodes through one lane-executor call, so independent tree nodes
//!   fill SIMD lanes together. O(k·R) resident keys, any stream length.
//! * [`extsort`] — pipelined run formation (sharded across cores behind
//!   a bounded chunk queue) + segmented spill + multi-pass streaming
//!   merge with rolling segment deletion: sorts arbitrarily large
//!   inputs (in-memory slices or files of little-endian `u32` keys) in
//!   bounded memory. Backs the `loms sort` CLI and replaces the
//!   planner's scalar heap as its phase-3 engine.
//! * [`io`] — the disk plumbing underneath: bulk LE codecs, prefetch /
//!   write-behind overlap threads, spill-file drop guards, per-block
//!   CRC-32 spill integrity (sidecar format + verified reader with
//!   bounded re-read recovery, typed [`ExtSortError`]s), and the
//!   producer/worker/sink run-formation pipeline.
//! * [`part`] — sampling-based range partitioning for the final pass:
//!   P independent merge trees over exact per-run cuts produce the
//!   byte-identical output of one tree, on P cores.
//! * [`kv`] — the key-value twin of the whole stack: every key carries
//!   a `u64` payload that never enters a compare-exchange. Keys run the
//!   rank-then-permute lowering (packed with origin ranks through the
//!   unmodified CAS stream); the emitted permutation gathers each
//!   payload column once per node step.

pub mod extsort;
pub mod io;
pub mod kv;
pub mod merge2;
pub mod part;
pub mod source;
pub mod tree;

pub use extsort::{extsort, extsort_file, extsort_with, ExtSortConfig, ExtSortStats, RunFormer};
pub use io::{
    decode_block_meta, encode_block_meta, encode_keys_into, encode_records_into, sidecar_path,
    ExtSortError, IoWait, SpillBlockMeta, SpillGuard, SPILL_BLOCK_RECS, SPILL_MAGIC,
    SPILL_META_BYTES, SPILL_VERSION,
};
pub use kv::{
    boxed_kv, extsort_kv, extsort_kv_file, merge_k_kv, merge_runs_kv, BlockKernelKv,
    BlockMerger2Kv, FileRunKvStream, MergeTreeKv, PrefetchRunKvStream, SliceKvStream,
    SortedKvStream, SpillRunKvStream, VecKvStream,
};
pub use merge2::{BlockKernel, BlockMerger2};
pub use part::{merge_runs_kv_parallel, merge_runs_parallel};
pub use source::{
    boxed, FileRunStream, IterStream, PrefetchRunStream, SliceStream, SortedStream,
    SpillRunStream, VecStream,
};
pub use tree::{merge_k, merge_runs, MergeTree, TreeStats, DEFAULT_R};
