//! Sampling-based range partitioning for the final merge pass.
//!
//! The last pass of an external sort merges k sorted runs once — the
//! one place a single merge tree serializes the whole output. Because
//! every run is sorted, the key domain splits exactly: sample each
//! run's keys, pick P−1 pivots at the sample quantiles, and cut every
//! run at `partition_point(key < pivot)`. Partition p then holds
//! precisely the keys in `[pivot[p−1], pivot[p])` from every run, so P
//! independent [`MergeTree`]s produce disjoint, contiguous spans of the
//! global output — concatenation (or P seeked writers into one
//! pre-sized file) reproduces the single-tree output **byte for byte**.
//! Duplicates of a pivot all land in the right-hand partition, so equal
//! keys never straddle a boundary and the key-value engine's stability
//! (arrival order among equal keys) survives partitioning.
//!
//! This is the software rendering of the IPS2Ra-style sampling
//! classifier the ROADMAP grounds phase 3 in; the merge inside each
//! partition stays the paper's LOMS tile kernel.

use super::kv::{boxed_kv, merge_runs_kv, MergeTreeKv, SliceKvStream, SortedKvStream};
use super::source::{boxed, SliceStream, SortedStream};
use super::tree::{merge_runs, MergeTree, TreeStats};
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

/// Keys drained from a partition tree per step.
const DRAIN: usize = 4096;

/// Keys sampled per run when picking pivots.
const SAMPLES_PER_RUN: usize = 32;

/// Smallest worthwhile partition (keys) when auto-sizing.
const MIN_PART_KEYS: usize = 1 << 15;

/// Resolve a partition-count request: `0` = auto (one per core, but
/// never smaller than [`MIN_PART_KEYS`]-key partitions), explicit
/// values honored as given.
pub(crate) fn resolve_partitions(requested: usize, total_keys: usize) -> usize {
    if requested != 0 {
        return requested;
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    cores.min((total_keys / MIN_PART_KEYS).max(1)).min(64)
}

/// Resolve a worker-thread request: `0` = auto (one per core).
pub(crate) fn resolve_threads(requested: usize) -> usize {
    if requested != 0 {
        return requested;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(64)
}

/// P−1 ascending pivots from pooled run samples (sorted here), at the
/// sample quantiles. Deduplicated — duplicate-heavy inputs yield fewer
/// effective partitions rather than empty ones.
pub(crate) fn pivots_from_samples(mut samples: Vec<u32>, parts: usize) -> Vec<u32> {
    if samples.is_empty() || parts <= 1 {
        return Vec::new();
    }
    samples.sort_unstable();
    let mut pivots: Vec<u32> =
        (1..parts).map(|p| samples[p * samples.len() / parts]).collect();
    pivots.dedup();
    pivots
}

/// Evenly spaced samples from one in-memory sorted run.
pub(crate) fn sample_slice(run: &[u32], out: &mut Vec<u32>) {
    let s = SAMPLES_PER_RUN.min(run.len());
    for j in 0..s {
        out.push(run[j * run.len() / s]);
    }
}

/// Cut boundaries for one sorted run: `[0, c_1, …, c_{P−1}, len]` with
/// `c_p = partition_point(key < pivot_p)` — exact because the run is
/// sorted, monotone because the pivots are.
pub(crate) fn cut_slice(run: &[u32], pivots: &[u32]) -> Vec<usize> {
    let mut bounds = Vec::with_capacity(pivots.len() + 2);
    bounds.push(0);
    for &pv in pivots {
        bounds.push(run.partition_point(|&k| k < pv));
    }
    bounds.push(run.len());
    bounds
}

/// Sampling and boundary search over one sorted run inside a spill
/// file, by seeked point reads — `stride` bytes per record, key in the
/// first 4 bytes little-endian (4 = key-only spill, 12 = KV spill).
/// O(samples + pivots·log len) reads, so cut discovery costs a few
/// hundred random 4-byte reads per run however large the spill.
///
/// These point reads deliberately skip checksum verification (each
/// would round up to a full block): corrupt keys can only skew where
/// the cuts land, and the final merges guard against that — cut rows
/// are checked for monotonicity before sizing, and every record then
/// streams through the block-verified spill reader.
pub(crate) struct FileCutter {
    file: File,
    start: u64,
    len: u64,
    stride: u64,
}

impl FileCutter {
    pub(crate) fn open(path: &Path, start: u64, len: u64, stride: u64) -> Result<FileCutter> {
        let file = File::open(path)
            .with_context(|| format!("opening run file {} for cuts", path.display()))?;
        Ok(FileCutter { file, start, len, stride })
    }

    fn key_at(&mut self, idx: u64) -> Result<u32> {
        let mut b = [0u8; 4];
        self.file
            .seek(SeekFrom::Start((self.start + idx) * self.stride))
            .and_then(|_| self.file.read_exact(&mut b))
            .context("point-reading run key for partition cut")?;
        Ok(u32::from_le_bytes(b))
    }

    pub(crate) fn sample_into(&mut self, out: &mut Vec<u32>) -> Result<()> {
        let s = (SAMPLES_PER_RUN as u64).min(self.len);
        for j in 0..s {
            let key = self.key_at(j * self.len / s)?;
            out.push(key);
        }
        Ok(())
    }

    /// Record-index boundaries `[0, c_1, …, len]` for `pivots`.
    pub(crate) fn cuts(&mut self, pivots: &[u32]) -> Result<Vec<u64>> {
        let mut bounds = Vec::with_capacity(pivots.len() + 2);
        bounds.push(0);
        for &pv in pivots {
            let (mut lo, mut hi) = (0u64, self.len);
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                if self.key_at(mid)? < pv {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            bounds.push(lo);
        }
        bounds.push(self.len);
        Ok(bounds)
    }
}

/// Merge in-memory sorted runs across `partitions` range-partitioned
/// merge trees on as many threads (`0` = auto). Output is identical to
/// [`merge_runs`] — partitioning only parallelizes, never reorders.
pub fn merge_runs_parallel(runs: &[Vec<u32>], r: usize, partitions: usize) -> Result<Vec<u32>> {
    Ok(merge_runs_parallel_stats(runs, r, partitions)?.0)
}

/// [`merge_runs_parallel`] plus (effective partitions, pooled tree
/// stats) — the external sorter's in-memory final pass.
pub(crate) fn merge_runs_parallel_stats(
    runs: &[Vec<u32>],
    r: usize,
    partitions: usize,
) -> Result<(Vec<u32>, usize, TreeStats)> {
    let total: usize = runs.iter().map(Vec::len).sum();
    let parts = resolve_partitions(partitions, total);
    if parts <= 1 || runs.len() <= 1 || total == 0 {
        return Ok((merge_runs(runs, r)?, 1, TreeStats::default()));
    }
    let mut samples = Vec::new();
    for run in runs {
        sample_slice(run, &mut samples);
    }
    let pivots = pivots_from_samples(samples, parts);
    let cuts: Vec<Vec<usize>> = runs.iter().map(|run| cut_slice(run, &pivots)).collect();
    let nparts = pivots.len() + 1;
    let sizes: Vec<usize> =
        (0..nparts).map(|p| cuts.iter().map(|c| c[p + 1] - c[p]).sum()).collect();
    let mut out = vec![0u32; total];
    let mut stats = TreeStats::default();
    {
        let mut regions: Vec<&mut [u32]> = Vec::with_capacity(nparts);
        let mut rest = out.as_mut_slice();
        for &sz in &sizes {
            let (a, b) = std::mem::take(&mut rest).split_at_mut(sz);
            regions.push(a);
            rest = b;
        }
        let cuts = &cuts;
        let part_stats = std::thread::scope(|s| {
            let handles: Vec<_> = regions
                .into_iter()
                .enumerate()
                .map(|(p, region)| {
                    s.spawn(move || -> Result<TreeStats> {
                        let streams: Vec<Box<dyn SortedStream + '_>> = runs
                            .iter()
                            .enumerate()
                            .filter(|(i, _)| cuts[*i][p + 1] > cuts[*i][p])
                            .map(|(i, run)| boxed(SliceStream::new(&run[cuts[i][p]..cuts[i][p + 1]])))
                            .collect();
                        let mut tree = MergeTree::new(streams, r)?;
                        drain_into_region(&mut tree, region)?;
                        Ok(tree.stats())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().map_err(|_| anyhow::anyhow!("partition merge panicked"))?)
                .collect::<Result<Vec<TreeStats>>>()
        })?;
        for st in part_stats {
            stats.absorb(st);
        }
    }
    Ok((out, nparts, stats))
}

/// Drain `tree` exactly into `region`, erroring on any size mismatch
/// (a cut bug would show up here, not as silent corruption).
fn drain_into_region(tree: &mut MergeTree<'_>, region: &mut [u32]) -> Result<()> {
    let mut filled = 0usize;
    let mut chunk = Vec::with_capacity(DRAIN);
    loop {
        chunk.clear();
        let n = tree.next_chunk(DRAIN, &mut chunk)?;
        if n == 0 {
            break;
        }
        anyhow::ensure!(filled + n <= region.len(), "partition produced too many keys");
        region[filled..filled + n].copy_from_slice(&chunk);
        filled += n;
    }
    anyhow::ensure!(filled == region.len(), "partition produced too few keys");
    Ok(())
}

/// Key-value twin of [`merge_runs_parallel`]: identical output to
/// [`merge_runs_kv`], including arrival order among equal keys (all
/// duplicates of a pivot land in one partition).
pub fn merge_runs_kv_parallel(
    runs: &[(Vec<u32>, Vec<u64>)],
    r: usize,
    partitions: usize,
) -> Result<(Vec<u32>, Vec<u64>)> {
    let (k, p, _, _) = merge_runs_kv_parallel_stats(runs, r, partitions)?;
    Ok((k, p))
}

/// [`merge_runs_kv_parallel`] plus (effective partitions, pooled tree
/// stats) — the KV external sorter's in-memory final pass.
pub(crate) fn merge_runs_kv_parallel_stats(
    runs: &[(Vec<u32>, Vec<u64>)],
    r: usize,
    partitions: usize,
) -> Result<(Vec<u32>, Vec<u64>, usize, TreeStats)> {
    let total: usize = runs.iter().map(|(k, _)| k.len()).sum();
    let parts = resolve_partitions(partitions, total);
    if parts <= 1 || runs.len() <= 1 || total == 0 {
        let (k, p) = merge_runs_kv(runs, r)?;
        return Ok((k, p, 1, TreeStats::default()));
    }
    let mut samples = Vec::new();
    for (keys, _) in runs {
        sample_slice(keys, &mut samples);
    }
    let pivots = pivots_from_samples(samples, parts);
    let cuts: Vec<Vec<usize>> = runs.iter().map(|(k, _)| cut_slice(k, &pivots)).collect();
    let nparts = pivots.len() + 1;
    let sizes: Vec<usize> =
        (0..nparts).map(|p| cuts.iter().map(|c| c[p + 1] - c[p]).sum()).collect();
    let mut out_k = vec![0u32; total];
    let mut out_p = vec![0u64; total];
    let mut stats = TreeStats::default();
    {
        let mut regions: Vec<(&mut [u32], &mut [u64])> = Vec::with_capacity(nparts);
        let (mut rest_k, mut rest_p) = (out_k.as_mut_slice(), out_p.as_mut_slice());
        for &sz in &sizes {
            let (ak, bk) = std::mem::take(&mut rest_k).split_at_mut(sz);
            let (ap, bp) = std::mem::take(&mut rest_p).split_at_mut(sz);
            regions.push((ak, ap));
            rest_k = bk;
            rest_p = bp;
        }
        let cuts = &cuts;
        let part_stats = std::thread::scope(|s| {
            let handles: Vec<_> = regions
                .into_iter()
                .enumerate()
                .map(|(p, (reg_k, reg_p))| {
                    s.spawn(move || -> Result<TreeStats> {
                        let streams: Vec<Box<dyn SortedKvStream + '_>> = runs
                            .iter()
                            .enumerate()
                            .filter(|(i, _)| cuts[*i][p + 1] > cuts[*i][p])
                            .map(|(i, (rk, rp))| {
                                boxed_kv(SliceKvStream::new(
                                    &rk[cuts[i][p]..cuts[i][p + 1]],
                                    &rp[cuts[i][p]..cuts[i][p + 1]],
                                ))
                            })
                            .collect();
                        let mut tree = MergeTreeKv::new(streams, r)?;
                        drain_into_regions_kv(&mut tree, reg_k, reg_p)?;
                        Ok(tree.stats())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().map_err(|_| anyhow::anyhow!("partition merge panicked"))?)
                .collect::<Result<Vec<TreeStats>>>()
        })?;
        for st in part_stats {
            stats.absorb(st);
        }
    }
    Ok((out_k, out_p, nparts, stats))
}

/// KV twin of [`drain_into_region`].
fn drain_into_regions_kv(
    tree: &mut MergeTreeKv<'_>,
    reg_k: &mut [u32],
    reg_p: &mut [u64],
) -> Result<()> {
    let mut filled = 0usize;
    let (mut ck, mut cp) = (Vec::with_capacity(DRAIN), Vec::with_capacity(DRAIN));
    loop {
        ck.clear();
        cp.clear();
        let n = tree.next_chunk(DRAIN, &mut ck, &mut cp)?;
        if n == 0 {
            break;
        }
        anyhow::ensure!(filled + n <= reg_k.len(), "partition produced too many pairs");
        reg_k[filled..filled + n].copy_from_slice(&ck);
        reg_p[filled..filled + n].copy_from_slice(&cp);
        filled += n;
    }
    anyhow::ensure!(filled == reg_k.len(), "partition produced too few pairs");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn cuts_are_exact_and_monotone() {
        let run = vec![1u32, 3, 3, 3, 7, 9, 9, 20];
        let pivots = vec![3u32, 9, 15];
        let c = cut_slice(&run, &pivots);
        assert_eq!(c, vec![0, 1, 5, 7, 8]);
        // Every key < pivot left of the cut, every key >= pivot right.
        for (pi, &pv) in pivots.iter().enumerate() {
            assert!(run[..c[pi + 1]].iter().all(|&k| k < pv));
            assert!(run[c[pi + 1]..].iter().all(|&k| k >= pv));
        }
    }

    #[test]
    fn parallel_merge_matches_single_tree() {
        let mut rng = Rng::new(0x9A37);
        for &k in &[2usize, 5, 9] {
            for &parts in &[2usize, 3, 7] {
                let runs: Vec<Vec<u32>> =
                    (0..k).map(|_| rng.sorted_list_ragged(0, 400, u32::MAX)).collect();
                let want = merge_runs(&runs, 8).unwrap();
                let got = merge_runs_parallel(&runs, 8, parts).unwrap();
                assert_eq!(got, want, "k={k} parts={parts}");
            }
        }
    }

    #[test]
    fn duplicate_heavy_runs_keep_stability_across_partitions() {
        // Few distinct keys force duplicates to straddle naive splits;
        // the cut rule must keep payload arrival order identical to the
        // single tree.
        let mut rng = Rng::new(0x9A38);
        let runs: Vec<(Vec<u32>, Vec<u64>)> = (0..6)
            .map(|i| {
                let mut keys: Vec<u32> = (0..500).map(|_| rng.next_u32() % 5).collect();
                keys.sort_unstable();
                let pays = (0..keys.len() as u64).map(|t| ((i as u64) << 32) | t).collect();
                (keys, pays)
            })
            .collect();
        let want = merge_runs_kv(&runs, 8).unwrap();
        for &parts in &[2usize, 4, 16] {
            let got = merge_runs_kv_parallel(&runs, 8, parts).unwrap();
            assert_eq!(got, want, "parts={parts}");
        }
    }

    #[test]
    fn degenerate_partition_requests() {
        let runs = vec![vec![5u32, 6], vec![1u32, 9]];
        let want = merge_runs(&runs, 4).unwrap();
        for parts in [1usize, 2, 64] {
            assert_eq!(merge_runs_parallel(&runs, 4, parts).unwrap(), want);
        }
        assert_eq!(merge_runs_parallel(&[], 4, 8).unwrap(), Vec::<u32>::new());
    }
}
