//! Sorted input sources for the streaming merge engine.
//!
//! A [`SortedStream`] is a pull-based producer of ascending `u32` keys —
//! the streaming twin of the one-shot sorted lists the merge service
//! accepts. Streams may be unbounded; consumers pull bounded chunks and
//! never materialize the whole input. Unlike the service path, the full
//! `u32` domain is legal here, `u32::MAX` included: the engine tracks
//! fill counts instead of interpreting any sentinel value (see
//! [`super::merge2`]).
//!
//! Adapters cover the three deployment shapes:
//!
//! * [`SliceStream`] / [`VecStream`] — in-memory sorted runs (the
//!   planner's surviving runs, test fixtures).
//! * [`IterStream`] — any ascending iterator, including infinite ones
//!   (generators, decoded network feeds).
//! * [`FileRunStream`] — one sorted run inside a file of little-endian
//!   `u32` keys (the extsort spill format): seeks once, then reads
//!   sequentially through its own handle.
//! * [`PrefetchRunStream`] — the same run with a dedicated read-ahead
//!   thread (double buffering via [`super::io::FilePrefetch`]), so the
//!   merge tree never blocks on a cold read.
//! * [`SpillRunStream`] — the same run through the checksum-verifying
//!   [`super::io::SpillReader`]: every block is validated against the
//!   segment's CRC sidecar, with one bounded re-read on failure.

use super::io::{FilePrefetch, IoWait, SpillReader};
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

/// A stream of ascending `u32` keys, pulled in bounded chunks.
///
/// Contract: keys are ascending across the *whole* stream (duplicates
/// allowed), and `next_chunk` appends at most `max` keys to `out`,
/// returning how many it appended. Returning `0` means the stream is
/// exhausted — implementations must not return `0` transiently. A call
/// may return fewer than `max` keys while data remains (e.g. a read
/// straddling an internal buffer); callers that need a full block loop
/// until satisfied or exhausted.
pub trait SortedStream {
    fn next_chunk(&mut self, max: usize, out: &mut Vec<u32>) -> Result<usize>;
}

/// Box an adapter for [`super::tree::MergeTree`]'s input list.
pub fn boxed<'a>(s: impl SortedStream + 'a) -> Box<dyn SortedStream + 'a> {
    Box::new(s)
}

/// A borrowed sorted slice as a stream.
#[derive(Debug)]
pub struct SliceStream<'a> {
    data: &'a [u32],
    pos: usize,
}

impl<'a> SliceStream<'a> {
    pub fn new(data: &'a [u32]) -> Self {
        debug_assert!(data.windows(2).all(|w| w[0] <= w[1]), "slice not sorted");
        SliceStream { data, pos: 0 }
    }
}

impl SortedStream for SliceStream<'_> {
    fn next_chunk(&mut self, max: usize, out: &mut Vec<u32>) -> Result<usize> {
        let n = max.min(self.data.len() - self.pos);
        out.extend_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// An owned sorted run as a stream.
#[derive(Debug)]
pub struct VecStream {
    data: Vec<u32>,
    pos: usize,
}

impl VecStream {
    pub fn new(data: Vec<u32>) -> Self {
        debug_assert!(data.windows(2).all(|w| w[0] <= w[1]), "run not sorted");
        VecStream { data, pos: 0 }
    }
}

impl SortedStream for VecStream {
    fn next_chunk(&mut self, max: usize, out: &mut Vec<u32>) -> Result<usize> {
        let n = max.min(self.data.len() - self.pos);
        out.extend_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Any ascending iterator as a stream — the unbounded-input adapter.
#[derive(Debug)]
pub struct IterStream<I> {
    iter: I,
    #[cfg(debug_assertions)]
    last: Option<u32>,
}

impl<I: Iterator<Item = u32>> IterStream<I> {
    pub fn new(iter: I) -> Self {
        IterStream {
            iter,
            #[cfg(debug_assertions)]
            last: None,
        }
    }
}

impl<I: Iterator<Item = u32>> SortedStream for IterStream<I> {
    fn next_chunk(&mut self, max: usize, out: &mut Vec<u32>) -> Result<usize> {
        let mut n = 0;
        while n < max {
            let Some(x) = self.iter.next() else { break };
            #[cfg(debug_assertions)]
            {
                debug_assert!(self.last.map_or(true, |p| p <= x), "iterator not ascending");
                self.last = Some(x);
            }
            out.push(x);
            n += 1;
        }
        Ok(n)
    }
}

/// One sorted run inside a file of little-endian `u32` keys — the
/// extsort spill format. Each run stream owns its own handle (one seek
/// at open, sequential reads after), so any number of runs of the same
/// file merge concurrently.
#[derive(Debug)]
pub struct FileRunStream {
    file: File,
    /// Keys left to read.
    remaining: u64,
    /// Reusable byte buffer for bulk reads.
    buf: Vec<u8>,
}

impl FileRunStream {
    /// Open the run spanning keys `[start, start + keys)` of `path`.
    pub fn open(path: &Path, start: u64, keys: u64) -> Result<Self> {
        let mut file =
            File::open(path).with_context(|| format!("opening run file {}", path.display()))?;
        file.seek(SeekFrom::Start(start * 4))
            .with_context(|| format!("seeking run at key {start} in {}", path.display()))?;
        Ok(FileRunStream { file, remaining: keys, buf: Vec::new() })
    }
}

impl SortedStream for FileRunStream {
    fn next_chunk(&mut self, max: usize, out: &mut Vec<u32>) -> Result<usize> {
        let n = (max as u64).min(self.remaining) as usize;
        if n == 0 {
            return Ok(0);
        }
        self.buf.resize(n * 4, 0);
        self.file.read_exact(&mut self.buf).context("reading spill run")?;
        out.extend(self.buf.chunks_exact(4).map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]])));
        self.remaining -= n as u64;
        Ok(n)
    }
}

/// [`FileRunStream`] with a dedicated read-ahead thread: buffer B fills
/// while the merge tree drains buffer A, so spill reads overlap with
/// merging. Stalls waiting for the reader are charged to the shared
/// [`IoWait`] counter.
pub struct PrefetchRunStream {
    fetch: FilePrefetch,
    buf: Vec<u8>,
    pos: usize,
}

impl PrefetchRunStream {
    /// Read ahead over keys `[start, start + keys)` of `path`,
    /// `buf_keys` keys per buffer.
    pub fn open(
        path: &Path,
        start: u64,
        keys: u64,
        buf_keys: usize,
        wait: IoWait,
    ) -> Result<Self> {
        let buf_bytes = buf_keys.max(1) * 4;
        let fetch = FilePrefetch::spawn(path, start * 4, keys * 4, buf_bytes, wait)?;
        Ok(PrefetchRunStream { fetch, buf: Vec::new(), pos: 0 })
    }
}

impl SortedStream for PrefetchRunStream {
    fn next_chunk(&mut self, max: usize, out: &mut Vec<u32>) -> Result<usize> {
        if self.pos == self.buf.len() {
            match self.fetch.next_buf()? {
                Some(b) => {
                    self.buf = b;
                    self.pos = 0;
                }
                None => return Ok(0),
            }
        }
        let n = max.min((self.buf.len() - self.pos) / 4);
        out.extend(
            self.buf[self.pos..self.pos + n * 4]
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]])),
        );
        self.pos += n * 4;
        Ok(n)
    }
}

/// A spill run read through the verified [`SpillReader`]: delivers the
/// same keys as [`FileRunStream`]/[`PrefetchRunStream`] over the same
/// byte layout, but each checksum block is verified against the
/// segment's `.crc` sidecar (bounded re-read recovery, typed
/// [`super::io::ExtSortError`] on unrecoverable corruption).
pub struct SpillRunStream {
    rd: SpillReader,
    carry: Vec<u32>,
    pos: usize,
}

impl SpillRunStream {
    /// Verified reads over keys `[start, start + keys)` of `path`.
    /// `prefetch_keys == 0` selects synchronous block reads.
    pub fn open(
        path: &Path,
        start: u64,
        keys: u64,
        prefetch_keys: usize,
        wait: IoWait,
    ) -> Result<Self> {
        let rd = SpillReader::open(path, start, keys, 4, prefetch_keys, wait)?;
        Ok(SpillRunStream { rd, carry: Vec::new(), pos: 0 })
    }
}

impl SortedStream for SpillRunStream {
    fn next_chunk(&mut self, max: usize, out: &mut Vec<u32>) -> Result<usize> {
        while self.pos == self.carry.len() {
            self.carry.clear();
            self.pos = 0;
            match self.rd.next_verified()? {
                Some(bytes) if !bytes.is_empty() => {
                    super::io::decode_keys_into(bytes, &mut self.carry)
                }
                Some(_) => continue,
                None => return Ok(0),
            }
        }
        let n = max.min(self.carry.len() - self.pos);
        out.extend_from_slice(&self.carry[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn drain(s: &mut dyn SortedStream, chunk: usize) -> Vec<u32> {
        let mut out = Vec::new();
        while s.next_chunk(chunk, &mut out).unwrap() > 0 {}
        out
    }

    #[test]
    fn slice_and_vec_streams_drain_in_chunks() {
        let data: Vec<u32> = (0..100).collect();
        assert_eq!(drain(&mut SliceStream::new(&data), 7), data);
        assert_eq!(drain(&mut VecStream::new(data.clone()), 100), data);
        assert_eq!(drain(&mut SliceStream::new(&[]), 4), Vec::<u32>::new());
    }

    #[test]
    fn iter_stream_supports_unbounded_sources() {
        // An infinite ascending iterator: pull a bounded prefix only.
        let mut s = IterStream::new((0u32..).map(|x| x * 2));
        let mut out = Vec::new();
        assert_eq!(s.next_chunk(5, &mut out).unwrap(), 5);
        assert_eq!(s.next_chunk(3, &mut out).unwrap(), 3);
        assert_eq!(out, vec![0, 2, 4, 6, 8, 10, 12, 14]);
    }

    #[test]
    fn file_run_stream_reads_its_window() {
        let path = std::env::temp_dir().join(format!("loms_runfile_{}.u32", std::process::id()));
        let keys: Vec<u32> = (0..50).map(|x| x * 3).collect();
        let mut f = File::create(&path).unwrap();
        for &k in &keys {
            f.write_all(&k.to_le_bytes()).unwrap();
        }
        drop(f);
        // Two runs over disjoint windows of the same file.
        let mut a = FileRunStream::open(&path, 0, 20).unwrap();
        let mut b = FileRunStream::open(&path, 20, 30).unwrap();
        assert_eq!(drain(&mut a, 7), keys[..20].to_vec());
        assert_eq!(drain(&mut b, 9), keys[20..].to_vec());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn prefetch_run_stream_matches_sync_reads() {
        let path =
            std::env::temp_dir().join(format!("loms_prefetch_{}.u32", std::process::id()));
        let keys: Vec<u32> = (0..1000).map(|x| x * 2).collect();
        let mut f = File::create(&path).unwrap();
        for &k in &keys {
            f.write_all(&k.to_le_bytes()).unwrap();
        }
        drop(f);
        // Tiny 16-key buffers force many refills; ragged chunk pulls
        // straddle buffer boundaries; the window excludes both file ends.
        let mut s = PrefetchRunStream::open(&path, 100, 800, 16, IoWait::new()).unwrap();
        assert_eq!(drain(&mut s, 7), keys[100..900].to_vec());
        // Dropping a half-drained stream joins its reader cleanly.
        let mut partial = PrefetchRunStream::open(&path, 0, 1000, 16, IoWait::new()).unwrap();
        let mut out = Vec::new();
        partial.next_chunk(5, &mut out).unwrap();
        drop(partial);
        let _ = std::fs::remove_file(path);
    }
}
