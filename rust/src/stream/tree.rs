//! Bounded-memory k-way merging: a binary tree of FLiMS-style block
//! mergers pumped through one shared R+R kernel.
//!
//! k sorted input streams feed the leaves; every internal node is a
//! [`BlockMerger2`] with a bounded output FIFO (2R keys). A scheduling
//! round scans nodes children-first, stages every node that can step —
//! both inputs resolvable (a key buffered, or provably exhausted) and
//! ≥ R keys of output space — and executes **all staged node steps as
//! one ragged batch** through the shared [`BlockKernel`]: independent
//! tree nodes fill SIMD lanes together, the way a hardware merge tree
//! keeps every pipeline stage busy (cf. the merge-tree compositions in
//! the sorting-hardware survey, arXiv:2310.07903).
//!
//! Memory is O(k·R) regardless of stream length: each leaf buffers ≤ R
//! keys, each node holds ≤ R retained + ≤ R staged + ≤ 2R FIFO keys,
//! and nothing is ever materialized whole — [`MergeTree`] is itself a
//! [`SortedStream`], so trees compose and the external sorter drains
//! the root incrementally ([`super::extsort`]).

use super::merge2::{BlockKernel, BlockMerger2};
use super::source::{boxed, SliceStream, SortedStream};
use anyhow::{bail, Result};

/// Default block size R — matches the smallest compiled 2-way artifact
/// shape (`loms2_up32_dn32`).
pub const DEFAULT_R: usize = 32;

/// Where a node (or the root) pulls keys from.
#[derive(Debug, Clone, Copy)]
enum Input {
    Leaf(usize),
    Node(usize),
}

/// What an input looks like at staging time.
#[derive(Debug, Clone, Copy)]
enum Peek {
    /// Next unconsumed key.
    Key(u32),
    /// Exhausted with nothing buffered (counts as +∞ for the refill rule).
    Exhausted,
    /// A child node that has not produced yet — wait for it.
    Pending,
}

/// A leaf: one input stream plus a ≤ R-key pull buffer.
struct LeafSource<'a> {
    stream: Box<dyn SortedStream + 'a>,
    buf: Vec<u32>,
    pos: usize,
    done: bool,
}

impl LeafSource<'_> {
    fn avail(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Buffer at least `want` keys, or everything left in the stream.
    fn fill_to(&mut self, want: usize) -> Result<()> {
        if self.done || self.avail() >= want {
            return Ok(());
        }
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        while self.buf.len() < want {
            let got = self.stream.next_chunk(want - self.buf.len(), &mut self.buf)?;
            if got == 0 {
                self.done = true;
                break;
            }
        }
        Ok(())
    }

    /// Next unconsumed key (`None` once the stream is drained).
    fn head(&mut self) -> Result<Option<u32>> {
        self.fill_to(1)?;
        Ok(self.buf.get(self.pos).copied())
    }

    /// Move up to `max` keys into `dst`; refills first so a live stream
    /// hands out full blocks.
    fn take(&mut self, max: usize, dst: &mut Vec<u32>) -> Result<usize> {
        self.fill_to(max)?;
        let n = max.min(self.avail());
        dst.extend_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// One internal merge node: the block merger plus its bounded output
/// FIFO (capacity 2R — the parent consumes ≤ R per step, the node
/// produces ≤ R per step, so 2R never deadlocks).
struct Node {
    left: Input,
    right: Input,
    merger: BlockMerger2,
    out: Vec<u32>,
    start: usize,
    /// Set when both inputs are exhausted and the retained tail has been
    /// flushed — the FIFO remainder is the node's final output.
    done: bool,
}

impl Node {
    fn avail(&self) -> usize {
        self.out.len() - self.start
    }

    fn head(&self) -> Option<u32> {
        self.out.get(self.start).copied()
    }

    fn compact(&mut self) {
        if self.start > 0 {
            self.out.drain(..self.start);
            self.start = 0;
        }
    }

    fn take(&mut self, max: usize, dst: &mut Vec<u32>) -> usize {
        let n = max.min(self.avail());
        dst.extend_from_slice(&self.out[self.start..self.start + n]);
        self.start += n;
        if self.start == self.out.len() {
            self.out.clear();
            self.start = 0;
        }
        n
    }
}

/// One staged node step, recorded between staging and apply.
struct Staged {
    node: usize,
    /// Emit count fixed at staging time (see [`BlockMerger2::emit_count`]).
    k: usize,
    /// Kernel output width (`h + m`).
    width: usize,
}

/// Scheduling counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct TreeStats {
    /// Kernel batch calls (one per scheduling round with work).
    pub kernel_batches: u64,
    /// Node steps executed (rows across all kernel batches).
    pub kernel_rows: u64,
    /// Endgame tail flushes.
    pub flushes: u64,
}

impl TreeStats {
    /// Pool another tree's counters into this one — the external sorter
    /// sums stats across passes and across partitioned final-merge trees.
    pub fn absorb(&mut self, other: TreeStats) {
        self.kernel_batches += other.kernel_batches;
        self.kernel_rows += other.kernel_rows;
        self.flushes += other.flushes;
    }
}

/// A k-way streaming merge: [`SortedStream`] in, [`SortedStream`] out,
/// O(k·R) resident keys.
pub struct MergeTree<'a> {
    r: usize,
    kernel: BlockKernel,
    leaves: Vec<LeafSource<'a>>,
    nodes: Vec<Node>,
    root: Option<Input>,
    staged: Vec<Staged>,
    /// Reusable per-row kernel output buffers.
    round_out: Vec<Vec<u32>>,
    stats: TreeStats,
}

/// Balanced binary tree over `leaves[lo..hi)`, children pushed before
/// parents so a scheduling scan in index order is children-first.
fn build(lo: usize, hi: usize, nodes: &mut Vec<Node>) -> Input {
    if hi - lo == 1 {
        return Input::Leaf(lo);
    }
    let mid = lo + (hi - lo) / 2;
    let left = build(lo, mid, nodes);
    let right = build(mid, hi, nodes);
    nodes.push(Node {
        left,
        right,
        merger: BlockMerger2::new(),
        out: Vec::new(),
        start: 0,
        done: false,
    });
    Input::Node(nodes.len() - 1)
}

fn peek_input(nodes: &[Node], leaves: &mut [LeafSource<'_>], inp: Input) -> Result<Peek> {
    Ok(match inp {
        Input::Leaf(l) => match leaves[l].head()? {
            Some(x) => Peek::Key(x),
            None => Peek::Exhausted,
        },
        Input::Node(c) => match nodes[c].head() {
            Some(x) => Peek::Key(x),
            None if nodes[c].done => Peek::Exhausted,
            None => Peek::Pending,
        },
    })
}

impl<'a> MergeTree<'a> {
    /// Build a merge tree over `streams` with block size `r`. `k = 0`
    /// yields an empty stream; `k = 1` passes the single input through.
    pub fn new(streams: Vec<Box<dyn SortedStream + 'a>>, r: usize) -> Result<MergeTree<'a>> {
        Ok(Self::with_kernel(streams, BlockKernel::new(r)?))
    }

    /// Build a tree around an already-compiled kernel — sequential
    /// trees of the same R (extsort's merge passes) hand one kernel
    /// from tree to tree via [`Self::into_kernel`] instead of paying
    /// the plan + lane compile per tree.
    pub fn with_kernel(
        streams: Vec<Box<dyn SortedStream + 'a>>,
        kernel: BlockKernel,
    ) -> MergeTree<'a> {
        let leaves: Vec<LeafSource<'a>> = streams
            .into_iter()
            .map(|s| LeafSource { stream: s, buf: Vec::new(), pos: 0, done: false })
            .collect();
        let mut nodes = Vec::new();
        let root = match leaves.len() {
            0 => None,
            n => Some(build(0, n, &mut nodes)),
        };
        MergeTree {
            r: kernel.r(),
            kernel,
            leaves,
            nodes,
            root,
            staged: Vec::new(),
            round_out: Vec::new(),
            stats: TreeStats::default(),
        }
    }

    /// Tear the tree down, recovering the kernel for the next tree.
    pub fn into_kernel(self) -> BlockKernel {
        self.kernel
    }

    pub fn stats(&self) -> TreeStats {
        self.stats
    }

    /// Block size R.
    pub fn r(&self) -> usize {
        self.r
    }

    /// Live keys held across all buffers right now — the working set.
    /// Bounded by O(k·R) whatever the input lengths (each leaf ≤ R,
    /// each node ≤ 4R counting FIFO + merger, each row buffer ≤ 2R).
    pub fn resident_keys(&self) -> usize {
        self.leaves.iter().map(|l| l.buf.len() - l.pos).sum::<usize>()
            + self.nodes.iter().map(|n| n.avail() + n.merger.width()).sum::<usize>()
            + self.round_out.iter().map(Vec::len).sum::<usize>()
    }

    /// One scheduling round: stage every steppable node, run one kernel
    /// batch over all staged rows, split each row into emit + retain.
    /// Returns whether anything progressed (a step or a flush).
    fn pump_round(&mut self) -> Result<bool> {
        let r = self.r;
        let cap = 2 * r;
        let MergeTree { kernel, leaves, nodes, staged, round_out, stats, .. } = self;
        staged.clear();
        let mut flushed = false;
        for n in 0..nodes.len() {
            if nodes[n].done {
                continue;
            }
            nodes[n].compact();
            if cap - nodes[n].avail() < r {
                continue; // output backpressure: wait for the parent
            }
            let (li, ri) = (nodes[n].left, nodes[n].right);
            let pl = peek_input(nodes, leaves, li)?;
            let pr = peek_input(nodes, leaves, ri)?;
            // The refill rule: take the next block from the input whose
            // head is smaller (ties to the left; exhausted = +∞).
            let (chosen, other_head) = match (pl, pr) {
                (Peek::Pending, _) | (_, Peek::Pending) => continue,
                (Peek::Exhausted, Peek::Exhausted) => {
                    let node = &mut nodes[n];
                    let Node { merger, out, done, .. } = node;
                    merger.flush(out);
                    *done = true;
                    stats.flushes += 1;
                    flushed = true;
                    continue;
                }
                (Peek::Key(x), Peek::Key(y)) => {
                    if x <= y {
                        (li, Some(y))
                    } else {
                        (ri, Some(x))
                    }
                }
                (Peek::Key(_), Peek::Exhausted) => (li, None),
                (Peek::Exhausted, Peek::Key(_)) => (ri, None),
            };
            let taken = match chosen {
                Input::Leaf(l) => {
                    let node = &mut nodes[n];
                    leaves[l].take(r, node.merger.stage_buf())?
                }
                Input::Node(c) => {
                    // Children index below parents (post-order build).
                    let (head, tail) = nodes.split_at_mut(n);
                    head[c].take(r, tail[0].merger.stage_buf())
                }
            };
            debug_assert!(taken >= 1, "chosen input had a peeked key");
            let k = nodes[n].merger.emit_count(other_head);
            let width = nodes[n].merger.width();
            staged.push(Staged { node: n, k, width });
        }
        if staged.is_empty() {
            return Ok(flushed);
        }
        // One ragged kernel batch over every staged node step.
        if round_out.len() < staged.len() {
            round_out.resize_with(staged.len(), Vec::new);
        }
        for (s, st) in staged.iter().enumerate() {
            round_out[s].clear();
            round_out[s].resize(st.width, 0);
        }
        let rows: Vec<&[Vec<u32>]> =
            staged.iter().map(|st| nodes[st.node].merger.lists()).collect();
        let mut outs: Vec<&mut [u32]> =
            round_out[..staged.len()].iter_mut().map(|v| v.as_mut_slice()).collect();
        kernel.merge_rows(&rows, &mut outs);
        stats.kernel_batches += 1;
        stats.kernel_rows += staged.len() as u64;
        for (s, st) in staged.iter().enumerate() {
            let Node { merger, out, .. } = &mut nodes[st.node];
            merger.apply(&round_out[s], st.k, out);
        }
        Ok(true)
    }
}

impl SortedStream for MergeTree<'_> {
    fn next_chunk(&mut self, max: usize, out: &mut Vec<u32>) -> Result<usize> {
        let Some(root) = self.root else { return Ok(0) };
        match root {
            // k = 1: pass the single stream through its leaf buffer.
            Input::Leaf(l) => self.leaves[l].take(max, out),
            Input::Node(ri) => loop {
                let n = self.nodes[ri].take(max, out);
                if n > 0 {
                    return Ok(n);
                }
                if self.nodes[ri].done {
                    return Ok(0);
                }
                if !self.pump_round()? {
                    // Unreachable by construction (an empty-FIFO node
                    // always has space, recursing to always-resolvable
                    // leaves) — fail loudly rather than spin.
                    bail!("streaming merge tree stalled");
                }
            },
        }
    }
}

/// Merge k sorted streams into a `Vec` (convenience over [`MergeTree`]
/// for bounded inputs — the tree itself never materializes the input).
pub fn merge_k<'a>(streams: Vec<Box<dyn SortedStream + 'a>>, r: usize) -> Result<Vec<u32>> {
    let mut tree = MergeTree::new(streams, r)?;
    let mut out = Vec::new();
    while tree.next_chunk(4096, &mut out)? > 0 {}
    Ok(out)
}

/// Merge in-memory sorted runs — the planner's phase-3 entry point
/// (replaces the scalar binary heap with the tile-pumped tree).
pub fn merge_runs(runs: &[Vec<u32>], r: usize) -> Result<Vec<u32>> {
    let total: usize = runs.iter().map(Vec::len).sum();
    let streams: Vec<Box<dyn SortedStream + '_>> =
        runs.iter().map(|run| boxed(SliceStream::new(run))).collect();
    let mut tree = MergeTree::new(streams, r)?;
    let mut out = Vec::with_capacity(total);
    while tree.next_chunk(4096, &mut out)? > 0 {}
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::source::{IterStream, VecStream};
    use crate::util::Rng;

    fn sorted_concat(runs: &[Vec<u32>]) -> Vec<u32> {
        let mut all: Vec<u32> = runs.concat();
        all.sort_unstable();
        all
    }

    #[test]
    fn merges_small_k_exactly() {
        let runs = vec![vec![1, 5, 9], vec![2, 6], vec![], vec![3, 4, 7, 8]];
        assert_eq!(merge_runs(&runs, 4).unwrap(), sorted_concat(&runs));
    }

    #[test]
    fn degenerate_k() {
        assert_eq!(merge_k(vec![], 8).unwrap(), Vec::<u32>::new());
        let one: Vec<Box<dyn SortedStream>> = vec![boxed(VecStream::new(vec![3, 4, 5]))];
        assert_eq!(merge_k(one, 8).unwrap(), vec![3, 4, 5]);
        let runs = vec![vec![], vec![], vec![]];
        assert_eq!(merge_runs(&runs, 8).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn random_runs_across_k_and_r() {
        let mut rng = Rng::new(0x7EE);
        for &k in &[2usize, 3, 5, 8, 17] {
            for &r in &[2usize, 8, 32] {
                let runs: Vec<Vec<u32>> =
                    (0..k).map(|_| rng.sorted_list_ragged(0, 300, 5000)).collect();
                let got = merge_runs(&runs, r).unwrap();
                assert_eq!(got, sorted_concat(&runs), "k={k} r={r}");
            }
        }
    }

    #[test]
    fn unbounded_streams_drain_lazily_in_bounded_memory() {
        // Two infinite interleaved streams; pull a fixed prefix and
        // check the working set stays O(k·R).
        let r = 8;
        let streams: Vec<Box<dyn SortedStream>> = vec![
            boxed(IterStream::new((0u32..).map(|x| x * 2))),
            boxed(IterStream::new((0u32..).map(|x| x * 2 + 1))),
            boxed(IterStream::new((0u32..).map(|x| x * 4))),
        ];
        let mut tree = MergeTree::new(streams, r).unwrap();
        let mut out = Vec::new();
        while out.len() < 10_000 {
            assert!(tree.next_chunk(512, &mut out).unwrap() > 0);
            assert!(
                tree.resident_keys() <= 8 * 3 * 2 * r,
                "working set {} exceeds O(k·R)",
                tree.resident_keys()
            );
        }
        assert!(out.windows(2).all(|w| w[0] <= w[1]));
        // Exact prefix: every generator key up to the last drained key.
        let hi = *out.last().unwrap();
        let mut want: Vec<u32> = (0u32..).map(|x| x * 2).take_while(|&x| x <= hi).collect();
        want.extend((0u32..).map(|x| x * 2 + 1).take_while(|&x| x <= hi));
        want.extend((0u32..).map(|x| x * 4).take_while(|&x| x <= hi));
        want.sort_unstable();
        assert_eq!(out, want[..out.len()]);
    }

    #[test]
    fn trees_compose_as_streams() {
        // A MergeTree is itself a SortedStream: feed one as a leaf of
        // another.
        let mut rng = Rng::new(0xC0);
        let inner_runs: Vec<Vec<u32>> = (0..3).map(|_| rng.sorted_list(100, 1000)).collect();
        let outer_run = rng.sorted_list(150, 1000);
        let inner_streams: Vec<Box<dyn SortedStream + '_>> = inner_runs
            .iter()
            .map(|r| boxed(SliceStream::new(r)))
            .collect();
        let inner = MergeTree::new(inner_streams, 8).unwrap();
        let outer: Vec<Box<dyn SortedStream + '_>> =
            vec![boxed(inner), boxed(SliceStream::new(&outer_run))];
        let got = merge_k(outer, 8).unwrap();
        let mut want = inner_runs.concat();
        want.extend_from_slice(&outer_run);
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn stats_count_batched_rows() {
        let mut rng = Rng::new(9);
        let runs: Vec<Vec<u32>> = (0..17).map(|_| rng.sorted_list(500, 1 << 20)).collect();
        let streams: Vec<Box<dyn SortedStream + '_>> =
            runs.iter().map(|r| boxed(SliceStream::new(r))).collect();
        let mut tree = MergeTree::new(streams, 8).unwrap();
        let mut out = Vec::new();
        while tree.next_chunk(4096, &mut out).unwrap() > 0 {}
        assert_eq!(out, sorted_concat(&runs));
        let st = tree.stats();
        assert!(st.kernel_rows > st.kernel_batches, "rounds batch multiple nodes: {st:?}");
        assert_eq!(st.flushes, 16, "every internal node flushes once");
    }
}
