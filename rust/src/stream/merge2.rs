//! FLiMS-style 2-way block merging: bounded head buffers pumped through
//! the compiled `loms2` R+R kernel.
//!
//! The paper's merge networks are fixed-width block devices; this module
//! deploys one the way FLiMS (Papaphilippou et al.) deploys its R+R
//! merger — as the kernel inside an *unbounded* 2-way merge. Each merge
//! node keeps a retained **high buffer** (≤ R keys) and repeatedly:
//!
//! 1. picks the input whose next unconsumed key is smaller (the classic
//!    refill rule — exhausted inputs count as +∞),
//! 2. takes a block of up to R keys from it,
//! 3. merges `high ∪ block` through the R+R network in one pass,
//! 4. **emits the low cone** (a provably safe prefix, see
//!    [`BlockMerger2::emit_count`]) and **retains the high cone** as the
//!    next high buffer — one kernel run yields both.
//!
//! Padding never uses an interpreted sentinel: the kernel's ragged view
//! path fills short slots with `u32::MAX` *values*, but the merger
//! tracks real fill counts (`h`, `m`) and slices the sorted output by
//! count. Since the output of a merge network is determined by its
//! input multiset, the first `h + m` outputs equal the real multiset
//! even when genuine `u32::MAX` keys are present — so, unlike the
//! serving path, the full `u32` domain is legal here.
//!
//! [`BlockKernel`] owns the compiled artifacts ([`CompiledPlan`] +
//! [`LanePlan`]) and executes *batches* of independent node steps as
//! ragged view rows, so a merge tree fills SIMD lanes with unrelated
//! nodes ([`super::tree`]).

use crate::sortnet::lanes::{self, LanePlan, LaneScratch};
use crate::sortnet::loms;
use crate::sortnet::plan::CompiledPlan;
use anyhow::{anyhow, Result};

/// Value filling unused kernel slots. Never interpreted on read — the
/// merger slices outputs by tracked fill count — so real `u32::MAX`
/// keys are indistinguishable from fill only where that is harmless
/// (sorted outputs are determined by the input multiset).
pub(crate) const FILL: u32 = u32::MAX;

/// The compiled `loms2` R+R block kernel shared by every node of a
/// merge tree: scalar plan (sub-tile tails), lane plan (SIMD tiles) and
/// reusable scratch.
pub struct BlockKernel {
    r: usize,
    plan: CompiledPlan,
    lane: LanePlan,
    scratch: LaneScratch<u32>,
}

impl BlockKernel {
    /// Compile the `loms_2way(r, r, 2)` device into the two-tier
    /// executable form (pruned where the auto policy allows).
    pub fn new(r: usize) -> Result<Self> {
        anyhow::ensure!(r >= 1, "block size R must be >= 1");
        let d = loms::loms_2way(r, r, 2);
        let plan = CompiledPlan::compile_auto(&d).map_err(|e| anyhow!("{}: {e}", d.name))?;
        let lane = LanePlan::compile(&plan);
        Ok(BlockKernel { r, plan, lane, scratch: LaneScratch::new() })
    }

    /// Block size R (each input slot of the kernel).
    pub fn r(&self) -> usize {
        self.r
    }

    /// Compiled device name (diagnostics / stats).
    pub fn device_name(&self) -> &str {
        &self.plan.name
    }

    /// Execute one batch of independent node steps. `rows[i]` is a node's
    /// `[high, block]` pair (each list sorted, ≤ R keys); `outs[i]` must
    /// be exactly `h_i + m_i` wide and receives that node's merged keys.
    /// Rows from different tree nodes batch together — full tiles run
    /// lane-parallel (sharded across cores for large batches), the
    /// remainder through the scalar plan's view path.
    pub fn merge_rows(&mut self, rows: &[&[Vec<u32>]], outs: &mut [&mut [u32]]) {
        let BlockKernel { plan, lane, scratch, .. } = self;
        lanes::run_view_batch_auto(lane, plan, rows, FILL, scratch, outs)
            .expect("fast-mode lane execution is infallible on sorted blocks");
    }

    /// Scalar single-pair convenience (tests, tiny merges): merge two
    /// sorted lists (each ≤ R) and append the result to `out`.
    #[cfg(test)]
    fn merge_pair(&mut self, a: &[u32], b: &[u32], out: &mut Vec<u32>) {
        use crate::sortnet::exec::ExecMode;
        let lists = [a.to_vec(), b.to_vec()];
        let row: &[Vec<u32>] = &lists;
        let start = out.len();
        out.resize(start + a.len() + b.len(), 0);
        let dst = &mut out[start..];
        let mut scratch = crate::sortnet::plan::PlanScratch::new();
        self.plan
            .run_view_batch_into(&[row], FILL, ExecMode::Fast, &mut scratch, &mut [dst])
            .expect("fast-mode execution is infallible");
    }
}

/// One streaming 2-way merge node: the retained high buffer, the staged
/// input block, and the emit/retain arithmetic. Kernel-agnostic — the
/// caller runs `[high, block]` through [`BlockKernel::merge_rows`] (or
/// any bit-exact substitute) and hands the sorted result to
/// [`Self::apply`].
///
/// Caller contract (the refill rule): a block is always taken from the
/// input whose next unconsumed key is ≤ the other input's next key
/// (exhausted-and-empty inputs count as +∞). [`super::tree::MergeTree`]
/// enforces this; the safety proof below depends on it.
#[derive(Debug, Default)]
pub struct BlockMerger2 {
    /// `lists[0]` = high buffer (sorted, ≤ R), `lists[1]` = staged block
    /// (sorted, ≤ R) — exactly the kernel's two input slots.
    lists: [Vec<u32>; 2],
}

impl BlockMerger2 {
    pub fn new() -> Self {
        BlockMerger2::default()
    }

    /// The retained high buffer.
    pub fn high(&self) -> &[u32] {
        &self.lists[0]
    }

    /// The kernel row view (`[high, block]`).
    pub fn lists(&self) -> &[Vec<u32>] {
        &self.lists
    }

    /// Clear and return the staging buffer for the next block; the
    /// caller fills it with up to R keys from the chosen input.
    pub fn stage_buf(&mut self) -> &mut Vec<u32> {
        self.lists[1].clear();
        &mut self.lists[1]
    }

    /// Keys in flight (`h + m`) — the kernel output width for this row.
    pub fn width(&self) -> usize {
        self.lists[0].len() + self.lists[1].len()
    }

    /// How many of the merged `h + m` keys may be emitted this step.
    /// `other_head` is the non-chosen input's next unconsumed key
    /// (`None` when that input is exhausted with nothing buffered).
    ///
    /// Safety argument — with `S = high ∪ block`, emitted = the `k`
    /// smallest of `S`, every emitted key must precede every unconsumed
    /// key `u`:
    ///
    /// * `u` from the chosen input: the input is ascending, so
    ///   `u ≥ max(block)`; the k-th smallest of `S` is ≤ the k-th
    ///   smallest of `block` whenever `k ≤ m` — hence the `k ≤ m` cap.
    /// * `u` from the other input: `u ≥ other_head`. Every high-buffer
    ///   key is ≤ `other_head` (each was consumed while its origin's
    ///   head — then ≤ the other head by the refill rule — had not been
    ///   passed), and `cnt` block keys are ≤ `other_head` by direct
    ///   comparison; so ≥ `h + cnt` keys of `S` are ≤ `other_head`,
    ///   and any `k ≤ h + cnt` is safe.
    ///
    /// `k = min(m, h + cnt)` also bounds the retained tail: the new
    /// high buffer has `h + m − k ≤ max(h, m − 1) ≤ R` keys. In steady
    /// state (full R-blocks, both inputs live) this is the classic
    /// FLiMS schedule: emit R, retain R.
    pub fn emit_count(&self, other_head: Option<u32>) -> usize {
        let h = self.lists[0].len();
        let m = self.lists[1].len();
        let cnt = match other_head {
            None => m,
            Some(v) => self.lists[1].partition_point(|&x| x <= v),
        };
        m.min(h + cnt)
    }

    /// Consume one kernel output: `merged` is the sorted `h + m` keys of
    /// this node's row, `k` the emit count chosen at staging time. The
    /// low cone `merged[..k]` is appended to `emit`; the high cone
    /// becomes the new high buffer; the staged block is cleared.
    pub fn apply(&mut self, merged: &[u32], k: usize, emit: &mut Vec<u32>) {
        debug_assert_eq!(merged.len(), self.width());
        debug_assert!(k <= merged.len());
        emit.extend_from_slice(&merged[..k]);
        self.lists[0].clear();
        self.lists[0].extend_from_slice(&merged[k..]);
        self.lists[1].clear();
    }

    /// Endgame: both inputs exhausted and empty — the high buffer is the
    /// sorted remainder. Appends it to `emit` and leaves the node empty.
    pub fn flush(&mut self, emit: &mut Vec<u32>) {
        debug_assert!(self.lists[1].is_empty(), "flush with a staged block");
        emit.append(&mut self.lists[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn kernel_merges_ragged_pairs_exactly() {
        let mut k = BlockKernel::new(8).unwrap();
        assert_eq!(k.r(), 8);
        assert!(k.device_name().contains("loms"));
        let mut rng = Rng::new(0x57EA);
        for _ in 0..50 {
            let a = rng.sorted_list_ragged(0, 9, 1000);
            let b = rng.sorted_list_ragged(0, 9, 1000);
            let mut got = Vec::new();
            k.merge_pair(&a, &b, &mut got);
            let mut want = [a, b].concat();
            want.sort_unstable();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn kernel_batches_independent_rows() {
        // Rows from unrelated "nodes" (different widths) through one
        // batch call, across the tile boundary.
        let mut kern = BlockKernel::new(4).unwrap();
        let mut rng = Rng::new(0xBA7C);
        let n_rows = crate::sortnet::lanes::LANES + 5;
        let pairs: Vec<[Vec<u32>; 2]> = (0..n_rows)
            .map(|_| {
                [rng.sorted_list_ragged(0, 5, 100), rng.sorted_list_ragged(1, 5, 100)]
            })
            .collect();
        let rows: Vec<&[Vec<u32>]> = pairs.iter().map(|p| &p[..]).collect();
        let mut merged: Vec<Vec<u32>> =
            pairs.iter().map(|p| vec![0u32; p[0].len() + p[1].len()]).collect();
        let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
        kern.merge_rows(&rows, &mut outs);
        for (p, got) in pairs.iter().zip(&merged) {
            let mut want = [p[0].clone(), p[1].clone()].concat();
            want.sort_unstable();
            assert_eq!(&want, got);
        }
    }

    #[test]
    fn kernel_handles_max_value_keys_by_count() {
        // u32::MAX keys collide with the internal fill value; slicing by
        // count must still produce the exact multiset.
        let mut k = BlockKernel::new(4).unwrap();
        let a = vec![1, u32::MAX - 1, u32::MAX];
        let b = vec![u32::MAX - 1, u32::MAX];
        let mut got = Vec::new();
        k.merge_pair(&a, &b, &mut got);
        assert_eq!(got, vec![1, u32::MAX - 1, u32::MAX - 1, u32::MAX, u32::MAX]);
    }

    /// Drive the full refill loop over two in-memory streams with the
    /// real kernel — the mathematical core of the streaming engine,
    /// checked against std sort. Exercises ragged tails, duplicates,
    /// one-sided exhaustion and `u32::MAX` keys.
    fn run_two_stream(r: usize, a: &[u32], b: &[u32]) -> Vec<u32> {
        let mut kern = BlockKernel::new(r).unwrap();
        let mut node = BlockMerger2::new();
        let (mut pa, mut pb) = (0usize, 0usize);
        let mut out = Vec::new();
        loop {
            let (ha, hb) = (a.get(pa).copied(), b.get(pb).copied());
            let (src, pos, other) = match (ha, hb) {
                (None, None) => {
                    node.flush(&mut out);
                    return out;
                }
                (Some(x), Some(y)) if x <= y => (a, &mut pa, hb),
                (Some(_), Some(_)) => (b, &mut pb, ha),
                (Some(_), None) => (a, &mut pa, None),
                (None, Some(_)) => (b, &mut pb, None),
            };
            let m = r.min(src.len() - *pos);
            node.stage_buf().extend_from_slice(&src[*pos..*pos + m]);
            *pos += m;
            let k = node.emit_count(other);
            let mut merged = vec![0u32; node.width()];
            {
                let rows: Vec<&[Vec<u32>]> = vec![node.lists()];
                kern.merge_rows(&rows, &mut [&mut merged[..]]);
            }
            node.apply(&merged, k, &mut out);
            assert!(node.high().len() <= r, "retained tail exceeds R");
        }
    }

    #[test]
    fn block_merger_matches_sort_on_random_streams() {
        let mut rng = Rng::new(0xF11);
        for case in 0..40 {
            let r = [2usize, 3, 4, 8][rng.range(0, 4)];
            let la = rng.range(0, 200);
            let lb = rng.range(0, 200);
            let max = if case % 3 == 0 { 8 } else { 1 << 20 }; // duplicate-heavy mix
            let a = rng.sorted_list(la, max);
            let b = rng.sorted_list(lb, max);
            let got = run_two_stream(r, &a, &b);
            let mut want = [a, b].concat();
            want.sort_unstable();
            assert_eq!(got, want, "case {case} r={r} la={la} lb={lb}");
        }
    }

    #[test]
    fn block_merger_survives_sentinel_adjacent_keys() {
        // Regression: u32::MAX-1 / u32::MAX adjacent keys flow through
        // the count-tracked fill path without corruption.
        let a = vec![5, u32::MAX - 1, u32::MAX - 1, u32::MAX];
        let b = vec![u32::MAX - 1, u32::MAX, u32::MAX];
        let got = run_two_stream(2, &a, &b);
        let mut want = [a, b].concat();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn steady_state_emits_full_blocks() {
        // Balanced long inputs: after warmup every step runs the classic
        // full schedule — emit R, retain R.
        let r = 8;
        let a: Vec<u32> = (0..512).map(|x| x * 2).collect();
        let b: Vec<u32> = (0..512).map(|x| x * 2 + 1).collect();
        let mut node = BlockMerger2::new();
        node.stage_buf().extend_from_slice(&a[..r]);
        let mut scratch = Vec::new();
        let k0 = node.emit_count(Some(b[0]));
        let mut merged: Vec<u32> = node.lists().concat();
        merged.sort_unstable();
        node.apply(&merged, k0, &mut scratch);
        // Second step onward: full block staged against a full-ish high.
        node.stage_buf().extend_from_slice(&b[..r]);
        let k1 = node.emit_count(Some(a[r]));
        assert_eq!(k1, r, "steady state emits a full low block");
    }
}
