//! Key-value streaming merge: the [`super::merge2`] / [`super::tree`] /
//! [`super::extsort`] engine with a `u64` payload riding beside every
//! key — payloads never enter a compare-exchange.
//!
//! The kernel is the **rank-then-permute** lowering
//! ([`crate::sortnet::lanes::LanePlan::run_view_batch_perm_into`]): keys
//! packed with list-major origin ranks run through the unmodified CAS
//! stream, and the emitted permutation gathers each payload column once
//! per row. Everything above the kernel — the FLiMS emit/retain
//! arithmetic, the children-first tree scheduler, run formation and
//! spill passes — is the key-only engine with a payload vector carried
//! in lock-step beside every key buffer.
//!
//! Like the key-only stream engine (and unlike the serving path), fill
//! is tracked by count, so the full `u32` key domain is legal: a real
//! `u32::MAX` key packs below the `u64::MAX` pad because its origin
//! rank stays far below `u32::MAX`.
//!
//! Spill format: back-to-back 12-byte little-endian records, `u32` key
//! then `u64` payload ([`FileRunKvStream`]).

use crate::sortnet::lanes::{self, LanePlan, LaneScratch};
use crate::sortnet::loms;
use crate::sortnet::plan::CompiledPlan;
use anyhow::{anyhow, bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::extsort::{ExtSortConfig, ExtSortStats};
use super::tree::TreeStats;

/// Record pairs pulled from the merge tree per drain step.
const DRAIN: usize = 4096;

/// Bytes per spilled `(key, payload)` record.
const REC_BYTES: u64 = 12;

/// A stream of ascending `u32` keys with one `u64` payload each, pulled
/// in bounded chunks. Same contract as [`super::source::SortedStream`]:
/// keys ascending across the whole stream (duplicates allowed, payloads
/// ride with their key), `next_chunk` appends at most `max` pairs to
/// `keys`/`pays` in lock-step and returns the count; `0` means
/// exhausted, never transient.
pub trait SortedKvStream {
    fn next_chunk(&mut self, max: usize, keys: &mut Vec<u32>, pays: &mut Vec<u64>)
        -> Result<usize>;
}

/// Box an adapter for [`MergeTreeKv`]'s input list.
pub fn boxed_kv<'a>(s: impl SortedKvStream + 'a) -> Box<dyn SortedKvStream + 'a> {
    Box::new(s)
}

/// Borrowed sorted key/payload columns as a stream.
#[derive(Debug)]
pub struct SliceKvStream<'a> {
    keys: &'a [u32],
    pays: &'a [u64],
    pos: usize,
}

impl<'a> SliceKvStream<'a> {
    pub fn new(keys: &'a [u32], pays: &'a [u64]) -> Self {
        assert_eq!(keys.len(), pays.len(), "key/payload columns differ in length");
        debug_assert!(keys.windows(2).all(|w| w[0] <= w[1]), "keys not sorted");
        SliceKvStream { keys, pays, pos: 0 }
    }
}

impl SortedKvStream for SliceKvStream<'_> {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        let n = max.min(self.keys.len() - self.pos);
        keys.extend_from_slice(&self.keys[self.pos..self.pos + n]);
        pays.extend_from_slice(&self.pays[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Owned sorted key/payload columns as a stream.
#[derive(Debug)]
pub struct VecKvStream {
    keys: Vec<u32>,
    pays: Vec<u64>,
    pos: usize,
}

impl VecKvStream {
    pub fn new(keys: Vec<u32>, pays: Vec<u64>) -> Self {
        assert_eq!(keys.len(), pays.len(), "key/payload columns differ in length");
        debug_assert!(keys.windows(2).all(|w| w[0] <= w[1]), "keys not sorted");
        VecKvStream { keys, pays, pos: 0 }
    }
}

impl SortedKvStream for VecKvStream {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        let n = max.min(self.keys.len() - self.pos);
        keys.extend_from_slice(&self.keys[self.pos..self.pos + n]);
        pays.extend_from_slice(&self.pays[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// One sorted run inside a file of 12-byte little-endian `(u32 key,
/// u64 payload)` records — the key-value spill format. Mirrors
/// [`super::source::FileRunStream`]: one seek at open, sequential reads
/// after, each run stream owning its handle.
#[derive(Debug)]
pub struct FileRunKvStream {
    file: File,
    /// Records left to read.
    remaining: u64,
    /// Reusable byte buffer for bulk reads.
    buf: Vec<u8>,
}

impl FileRunKvStream {
    /// Open the run spanning records `[start, start + records)` of `path`.
    pub fn open(path: &Path, start: u64, records: u64) -> Result<Self> {
        let mut file =
            File::open(path).with_context(|| format!("opening KV run file {}", path.display()))?;
        file.seek(SeekFrom::Start(start * REC_BYTES))
            .with_context(|| format!("seeking KV run at record {start} in {}", path.display()))?;
        Ok(FileRunKvStream { file, remaining: records, buf: Vec::new() })
    }
}

impl SortedKvStream for FileRunKvStream {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        let n = (max as u64).min(self.remaining) as usize;
        if n == 0 {
            return Ok(0);
        }
        self.buf.resize(n * REC_BYTES as usize, 0);
        self.file.read_exact(&mut self.buf).context("reading KV spill run")?;
        for rec in self.buf.chunks_exact(REC_BYTES as usize) {
            keys.push(u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]));
            pays.push(u64::from_le_bytes([
                rec[4], rec[5], rec[6], rec[7], rec[8], rec[9], rec[10], rec[11],
            ]));
        }
        self.remaining -= n as u64;
        Ok(n)
    }
}

/// The compiled `loms2` R+R kernel on the rank-then-permute path:
/// scalar plan, lane plan, the packed `u64` tile scratch, and the
/// reusable flat permutation buffer the payload gather reads through.
pub struct BlockKernelKv {
    r: usize,
    plan: CompiledPlan,
    lane: LanePlan,
    scratch: LaneScratch<u64>,
    perm_buf: Vec<u32>,
}

impl BlockKernelKv {
    /// Compile the `loms_2way(r, r, 2)` device — the same device the
    /// key-only [`super::merge2::BlockKernel`] runs; only the lowering
    /// differs (packed keys + permutation output).
    pub fn new(r: usize) -> Result<Self> {
        anyhow::ensure!(r >= 1, "block size R must be >= 1");
        let d = loms::loms_2way(r, r, 2);
        let plan = CompiledPlan::compile_auto(&d).map_err(|e| anyhow!("{}: {e}", d.name))?;
        let lane = LanePlan::compile(&plan);
        Ok(BlockKernelKv { r, plan, lane, scratch: LaneScratch::new(), perm_buf: Vec::new() })
    }

    /// Block size R.
    pub fn r(&self) -> usize {
        self.r
    }

    /// Compiled device name (diagnostics / stats).
    pub fn device_name(&self) -> &str {
        &self.plan.name
    }

    /// Execute one batch of independent node steps. `rows[i]` is a
    /// node's `[high, block]` key pair; `pay_rows[i]` the matching
    /// payload pair; `out_keys[i]` / `out_pays[i]` are the equal-width
    /// (`h_i + m_i`) destinations. Keys run through the packed
    /// comparator tiles; each payload moves exactly once, gathered
    /// through the emitted permutation.
    pub fn merge_rows(
        &mut self,
        rows: &[&[Vec<u32>]],
        pay_rows: &[[&[u64]; 2]],
        out_keys: &mut [&mut [u32]],
        out_pays: &mut [&mut [u64]],
    ) {
        debug_assert_eq!(rows.len(), pay_rows.len());
        debug_assert_eq!(rows.len(), out_pays.len());
        let BlockKernelKv { plan, lane, scratch, perm_buf, .. } = self;
        // Split one flat reusable buffer into per-row permutation slices.
        let total: usize = out_keys.iter().map(|o| o.len()).sum();
        perm_buf.clear();
        perm_buf.resize(total, 0);
        let mut perm_outs: Vec<&mut [u32]> = Vec::with_capacity(rows.len());
        let mut rest = perm_buf.as_mut_slice();
        for o in out_keys.iter() {
            let (head, tail) = rest.split_at_mut(o.len());
            perm_outs.push(head);
            rest = tail;
        }
        lanes::run_view_batch_perm_auto(lane, plan, rows, scratch, out_keys, &mut perm_outs)
            .expect("fast-mode perm execution is infallible on sorted blocks");
        // The single payload move: origin ranks index the row's
        // list-major concatenation `[high, block]`.
        for (i, perm) in perm_outs.iter().enumerate() {
            let [p0, p1] = pay_rows[i];
            let dst = &mut *out_pays[i];
            for (t, &p) in perm.iter().enumerate() {
                let p = p as usize;
                dst[t] = if p < p0.len() { p0[p] } else { p1[p - p0.len()] };
            }
        }
    }
}

/// One streaming 2-way key-value merge node: [`super::merge2::BlockMerger2`]
/// with a payload vector in lock-step beside each key buffer. The
/// emit/retain arithmetic ([`Self::emit_count`]) reads keys only — its
/// safety proof is unchanged — and [`Self::apply`] moves the merged
/// payload column alongside the merged keys.
#[derive(Debug, Default)]
pub struct BlockMerger2Kv {
    /// `lists[0]` = high buffer, `lists[1]` = staged block — the
    /// kernel's two key slots.
    lists: [Vec<u32>; 2],
    /// Payload columns in lock-step with `lists`.
    pays: [Vec<u64>; 2],
}

impl BlockMerger2Kv {
    pub fn new() -> Self {
        BlockMerger2Kv::default()
    }

    /// The retained high-buffer keys.
    pub fn high(&self) -> &[u32] {
        &self.lists[0]
    }

    /// The kernel key-row view (`[high, block]`).
    pub fn lists(&self) -> &[Vec<u32>] {
        &self.lists
    }

    /// The kernel payload-row view (`[high, block]`).
    pub fn pay_slices(&self) -> [&[u64]; 2] {
        [&self.pays[0], &self.pays[1]]
    }

    /// Clear and return the staging buffers for the next block; the
    /// caller fills both in lock-step with up to R pairs.
    pub fn stage_bufs(&mut self) -> (&mut Vec<u32>, &mut Vec<u64>) {
        self.lists[1].clear();
        self.pays[1].clear();
        (&mut self.lists[1], &mut self.pays[1])
    }

    /// Pairs in flight (`h + m`) — the kernel output width for this row.
    pub fn width(&self) -> usize {
        self.lists[0].len() + self.lists[1].len()
    }

    /// How many merged pairs may be emitted this step — identical to
    /// [`super::merge2::BlockMerger2::emit_count`]: the bound depends
    /// only on key order, so the payload column cannot change it.
    pub fn emit_count(&self, other_head: Option<u32>) -> usize {
        let h = self.lists[0].len();
        let m = self.lists[1].len();
        let cnt = match other_head {
            None => m,
            Some(v) => self.lists[1].partition_point(|&x| x <= v),
        };
        m.min(h + cnt)
    }

    /// Consume one kernel output: the low cones of both columns are
    /// appended to `emit_k`/`emit_p`, the high cones become the new
    /// high buffers, the staged block is cleared.
    pub fn apply(
        &mut self,
        merged_keys: &[u32],
        merged_pays: &[u64],
        k: usize,
        emit_k: &mut Vec<u32>,
        emit_p: &mut Vec<u64>,
    ) {
        debug_assert_eq!(merged_keys.len(), self.width());
        debug_assert_eq!(merged_pays.len(), merged_keys.len());
        debug_assert!(k <= merged_keys.len());
        emit_k.extend_from_slice(&merged_keys[..k]);
        emit_p.extend_from_slice(&merged_pays[..k]);
        self.lists[0].clear();
        self.lists[0].extend_from_slice(&merged_keys[k..]);
        self.pays[0].clear();
        self.pays[0].extend_from_slice(&merged_pays[k..]);
        self.lists[1].clear();
        self.pays[1].clear();
    }

    /// Endgame: both inputs exhausted and empty — the high buffers are
    /// the sorted remainder.
    pub fn flush(&mut self, emit_k: &mut Vec<u32>, emit_p: &mut Vec<u64>) {
        debug_assert!(self.lists[1].is_empty(), "flush with a staged block");
        emit_k.append(&mut self.lists[0]);
        emit_p.append(&mut self.pays[0]);
    }
}

/// Where a node (or the root) pulls pairs from.
#[derive(Debug, Clone, Copy)]
enum Input {
    Leaf(usize),
    Node(usize),
}

/// What an input looks like at staging time.
#[derive(Debug, Clone, Copy)]
enum Peek {
    Key(u32),
    Exhausted,
    Pending,
}

/// A leaf: one input stream plus a ≤ R-pair pull buffer.
struct LeafKvSource<'a> {
    stream: Box<dyn SortedKvStream + 'a>,
    keys: Vec<u32>,
    pays: Vec<u64>,
    pos: usize,
    done: bool,
}

impl LeafKvSource<'_> {
    fn avail(&self) -> usize {
        self.keys.len() - self.pos
    }

    fn fill_to(&mut self, want: usize) -> Result<()> {
        if self.done || self.avail() >= want {
            return Ok(());
        }
        if self.pos > 0 {
            self.keys.drain(..self.pos);
            self.pays.drain(..self.pos);
            self.pos = 0;
        }
        while self.keys.len() < want {
            let got =
                self.stream.next_chunk(want - self.keys.len(), &mut self.keys, &mut self.pays)?;
            if got == 0 {
                self.done = true;
                break;
            }
        }
        Ok(())
    }

    fn head(&mut self) -> Result<Option<u32>> {
        self.fill_to(1)?;
        Ok(self.keys.get(self.pos).copied())
    }

    fn take(&mut self, max: usize, dst_k: &mut Vec<u32>, dst_p: &mut Vec<u64>) -> Result<usize> {
        self.fill_to(max)?;
        let n = max.min(self.avail());
        dst_k.extend_from_slice(&self.keys[self.pos..self.pos + n]);
        dst_p.extend_from_slice(&self.pays[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// One internal merge node: the KV block merger plus its bounded output
/// FIFO (capacity 2R pairs, same deadlock-freedom argument as
/// [`super::tree`]).
struct NodeKv {
    left: Input,
    right: Input,
    merger: BlockMerger2Kv,
    out_k: Vec<u32>,
    out_p: Vec<u64>,
    start: usize,
    done: bool,
}

impl NodeKv {
    fn avail(&self) -> usize {
        self.out_k.len() - self.start
    }

    fn head(&self) -> Option<u32> {
        self.out_k.get(self.start).copied()
    }

    fn compact(&mut self) {
        if self.start > 0 {
            self.out_k.drain(..self.start);
            self.out_p.drain(..self.start);
            self.start = 0;
        }
    }

    fn take(&mut self, max: usize, dst_k: &mut Vec<u32>, dst_p: &mut Vec<u64>) -> usize {
        let n = max.min(self.avail());
        dst_k.extend_from_slice(&self.out_k[self.start..self.start + n]);
        dst_p.extend_from_slice(&self.out_p[self.start..self.start + n]);
        self.start += n;
        if self.start == self.out_k.len() {
            self.out_k.clear();
            self.out_p.clear();
            self.start = 0;
        }
        n
    }
}

/// One staged node step, recorded between staging and apply.
struct Staged {
    node: usize,
    k: usize,
    width: usize,
}

/// A k-way streaming key-value merge: [`SortedKvStream`] in,
/// [`SortedKvStream`] out, O(k·R) resident pairs. The scheduler is
/// [`super::tree::MergeTree`]'s, verbatim — children-first scan, refill
/// rule with ties to the left, one ragged kernel batch per round — over
/// the rank-then-permute kernel.
pub struct MergeTreeKv<'a> {
    r: usize,
    kernel: BlockKernelKv,
    leaves: Vec<LeafKvSource<'a>>,
    nodes: Vec<NodeKv>,
    root: Option<Input>,
    staged: Vec<Staged>,
    round_out_k: Vec<Vec<u32>>,
    round_out_p: Vec<Vec<u64>>,
    stats: TreeStats,
}

/// Balanced binary tree over `leaves[lo..hi)`, children pushed before
/// parents so an index-order scan is children-first.
fn build(lo: usize, hi: usize, nodes: &mut Vec<NodeKv>) -> Input {
    if hi - lo == 1 {
        return Input::Leaf(lo);
    }
    let mid = lo + (hi - lo) / 2;
    let left = build(lo, mid, nodes);
    let right = build(mid, hi, nodes);
    nodes.push(NodeKv {
        left,
        right,
        merger: BlockMerger2Kv::new(),
        out_k: Vec::new(),
        out_p: Vec::new(),
        start: 0,
        done: false,
    });
    Input::Node(nodes.len() - 1)
}

fn peek_input(nodes: &[NodeKv], leaves: &mut [LeafKvSource<'_>], inp: Input) -> Result<Peek> {
    Ok(match inp {
        Input::Leaf(l) => match leaves[l].head()? {
            Some(x) => Peek::Key(x),
            None => Peek::Exhausted,
        },
        Input::Node(c) => match nodes[c].head() {
            Some(x) => Peek::Key(x),
            None if nodes[c].done => Peek::Exhausted,
            None => Peek::Pending,
        },
    })
}

impl<'a> MergeTreeKv<'a> {
    /// Build a merge tree over `streams` with block size `r`. `k = 0`
    /// yields an empty stream; `k = 1` passes the single input through.
    pub fn new(streams: Vec<Box<dyn SortedKvStream + 'a>>, r: usize) -> Result<MergeTreeKv<'a>> {
        Ok(Self::with_kernel(streams, BlockKernelKv::new(r)?))
    }

    /// Build a tree around an already-compiled kernel (sequential trees
    /// of the same R hand it from tree to tree via [`Self::into_kernel`]).
    pub fn with_kernel(
        streams: Vec<Box<dyn SortedKvStream + 'a>>,
        kernel: BlockKernelKv,
    ) -> MergeTreeKv<'a> {
        let leaves: Vec<LeafKvSource<'a>> = streams
            .into_iter()
            .map(|s| LeafKvSource {
                stream: s,
                keys: Vec::new(),
                pays: Vec::new(),
                pos: 0,
                done: false,
            })
            .collect();
        let mut nodes = Vec::new();
        let root = match leaves.len() {
            0 => None,
            n => Some(build(0, n, &mut nodes)),
        };
        MergeTreeKv {
            r: kernel.r(),
            kernel,
            leaves,
            nodes,
            root,
            staged: Vec::new(),
            round_out_k: Vec::new(),
            round_out_p: Vec::new(),
            stats: TreeStats::default(),
        }
    }

    /// Tear the tree down, recovering the kernel for the next tree.
    pub fn into_kernel(self) -> BlockKernelKv {
        self.kernel
    }

    pub fn stats(&self) -> TreeStats {
        self.stats
    }

    /// Block size R.
    pub fn r(&self) -> usize {
        self.r
    }

    /// One scheduling round — [`super::tree::MergeTree::pump_round`]
    /// with the payload columns carried beside every key buffer.
    fn pump_round(&mut self) -> Result<bool> {
        let r = self.r;
        let cap = 2 * r;
        let MergeTreeKv { kernel, leaves, nodes, staged, round_out_k, round_out_p, stats, .. } =
            self;
        staged.clear();
        let mut flushed = false;
        for n in 0..nodes.len() {
            if nodes[n].done {
                continue;
            }
            nodes[n].compact();
            if cap - nodes[n].avail() < r {
                continue; // output backpressure: wait for the parent
            }
            let (li, ri) = (nodes[n].left, nodes[n].right);
            let pl = peek_input(nodes, leaves, li)?;
            let pr = peek_input(nodes, leaves, ri)?;
            // The refill rule: take the next block from the input whose
            // head is smaller (ties to the left; exhausted = +∞).
            let (chosen, other_head) = match (pl, pr) {
                (Peek::Pending, _) | (_, Peek::Pending) => continue,
                (Peek::Exhausted, Peek::Exhausted) => {
                    let node = &mut nodes[n];
                    let NodeKv { merger, out_k, out_p, done, .. } = node;
                    merger.flush(out_k, out_p);
                    *done = true;
                    stats.flushes += 1;
                    flushed = true;
                    continue;
                }
                (Peek::Key(x), Peek::Key(y)) => {
                    if x <= y {
                        (li, Some(y))
                    } else {
                        (ri, Some(x))
                    }
                }
                (Peek::Key(_), Peek::Exhausted) => (li, None),
                (Peek::Exhausted, Peek::Key(_)) => (ri, None),
            };
            let taken = match chosen {
                Input::Leaf(l) => {
                    let node = &mut nodes[n];
                    let (bk, bp) = node.merger.stage_bufs();
                    leaves[l].take(r, bk, bp)?
                }
                Input::Node(c) => {
                    // Children index below parents (post-order build).
                    let (head, tail) = nodes.split_at_mut(n);
                    let (bk, bp) = tail[0].merger.stage_bufs();
                    head[c].take(r, bk, bp)
                }
            };
            debug_assert!(taken >= 1, "chosen input had a peeked key");
            let k = nodes[n].merger.emit_count(other_head);
            let width = nodes[n].merger.width();
            staged.push(Staged { node: n, k, width });
        }
        if staged.is_empty() {
            return Ok(flushed);
        }
        // One ragged kernel batch over every staged node step.
        if round_out_k.len() < staged.len() {
            round_out_k.resize_with(staged.len(), Vec::new);
            round_out_p.resize_with(staged.len(), Vec::new);
        }
        for (s, st) in staged.iter().enumerate() {
            round_out_k[s].clear();
            round_out_k[s].resize(st.width, 0);
            round_out_p[s].clear();
            round_out_p[s].resize(st.width, 0);
        }
        let rows: Vec<&[Vec<u32>]> =
            staged.iter().map(|st| nodes[st.node].merger.lists()).collect();
        let pay_rows: Vec<[&[u64]; 2]> =
            staged.iter().map(|st| nodes[st.node].merger.pay_slices()).collect();
        let mut out_keys: Vec<&mut [u32]> =
            round_out_k[..staged.len()].iter_mut().map(|v| v.as_mut_slice()).collect();
        let mut out_pays: Vec<&mut [u64]> =
            round_out_p[..staged.len()].iter_mut().map(|v| v.as_mut_slice()).collect();
        kernel.merge_rows(&rows, &pay_rows, &mut out_keys, &mut out_pays);
        stats.kernel_batches += 1;
        stats.kernel_rows += staged.len() as u64;
        for (s, st) in staged.iter().enumerate() {
            let NodeKv { merger, out_k, out_p, .. } = &mut nodes[st.node];
            merger.apply(&round_out_k[s], &round_out_p[s], st.k, out_k, out_p);
        }
        Ok(true)
    }
}

impl SortedKvStream for MergeTreeKv<'_> {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        let Some(root) = self.root else { return Ok(0) };
        match root {
            // k = 1: pass the single stream through its leaf buffer.
            Input::Leaf(l) => self.leaves[l].take(max, keys, pays),
            Input::Node(ri) => loop {
                let n = self.nodes[ri].take(max, keys, pays);
                if n > 0 {
                    return Ok(n);
                }
                if self.nodes[ri].done {
                    return Ok(0);
                }
                if !self.pump_round()? {
                    // Unreachable by construction — fail loudly rather
                    // than spin (same argument as the key-only tree).
                    bail!("streaming KV merge tree stalled");
                }
            },
        }
    }
}

/// Merge k sorted key-value streams into owned columns.
pub fn merge_k_kv<'a>(
    streams: Vec<Box<dyn SortedKvStream + 'a>>,
    r: usize,
) -> Result<(Vec<u32>, Vec<u64>)> {
    let mut tree = MergeTreeKv::new(streams, r)?;
    let mut keys = Vec::new();
    let mut pays = Vec::new();
    while tree.next_chunk(DRAIN, &mut keys, &mut pays)? > 0 {}
    Ok((keys, pays))
}

/// Merge in-memory sorted key-value runs.
pub fn merge_runs_kv(runs: &[(Vec<u32>, Vec<u64>)], r: usize) -> Result<(Vec<u32>, Vec<u64>)> {
    let streams: Vec<Box<dyn SortedKvStream + '_>> =
        runs.iter().map(|(k, p)| boxed_kv(SliceKvStream::new(k, p))).collect();
    merge_k_kv(streams, r)
}

/// LE-encode `(key, payload)` records into the reusable `bytes` buffer.
fn encode_records(keys: &[u32], pays: &[u64], bytes: &mut Vec<u8>) {
    debug_assert_eq!(keys.len(), pays.len());
    bytes.clear();
    bytes.reserve(keys.len() * REC_BYTES as usize);
    for (&k, &p) in keys.iter().zip(pays) {
        bytes.extend_from_slice(&k.to_le_bytes());
        bytes.extend_from_slice(&p.to_le_bytes());
    }
}

/// Monotonic KV spill-file id (pid keeps parallel processes apart).
fn next_spill_path(dir: &Path) -> PathBuf {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let id = NEXT.fetch_add(1, Ordering::Relaxed);
    dir.join(format!("loms-kvspill-{}-{id}.kv12", std::process::id()))
}

/// Append-only writer for a spill file of back-to-back sorted KV runs.
struct SpillWriterKv {
    w: BufWriter<File>,
    path: PathBuf,
    runs: Vec<(u64, u64)>,
    /// Records written so far.
    pos: u64,
    cur: Option<u64>,
    bytes: Vec<u8>,
}

impl SpillWriterKv {
    fn create(path: PathBuf) -> Result<SpillWriterKv> {
        let f = File::create(&path)
            .with_context(|| format!("creating KV spill file {}", path.display()))?;
        Ok(SpillWriterKv {
            w: BufWriter::new(f),
            path,
            runs: Vec::new(),
            pos: 0,
            cur: None,
            bytes: Vec::new(),
        })
    }

    fn begin_run(&mut self) {
        debug_assert!(self.cur.is_none());
        self.cur = Some(self.pos);
    }

    fn write_records(&mut self, keys: &[u32], pays: &[u64]) -> Result<()> {
        encode_records(keys, pays, &mut self.bytes);
        self.w.write_all(&self.bytes)?;
        self.pos += keys.len() as u64;
        Ok(())
    }

    fn end_run(&mut self) {
        let start = self.cur.take().expect("end_run without begin_run");
        self.runs.push((start, self.pos - start));
    }

    fn push_run(&mut self, keys: &[u32], pays: &[u64]) -> Result<()> {
        self.begin_run();
        self.write_records(keys, pays)?;
        self.end_run();
        Ok(())
    }

    fn finish(mut self) -> Result<(PathBuf, Vec<(u64, u64)>)> {
        self.w.flush()?;
        Ok((self.path, self.runs))
    }
}

/// Where the current generation of KV runs lives.
enum RunStoreKv {
    Mem(Vec<(Vec<u32>, Vec<u64>)>),
    File { path: PathBuf, runs: Vec<(u64, u64)> },
}

impl RunStoreKv {
    fn count(&self) -> usize {
        match self {
            RunStoreKv::Mem(runs) => runs.len(),
            RunStoreKv::File { runs, .. } => runs.len(),
        }
    }

    fn open(&self, lo: usize, hi: usize) -> Result<Vec<Box<dyn SortedKvStream + '_>>> {
        match self {
            RunStoreKv::Mem(runs) => Ok(runs[lo..hi]
                .iter()
                .map(|(k, p)| boxed_kv(SliceKvStream::new(k, p)))
                .collect()),
            RunStoreKv::File { path, runs } => runs[lo..hi]
                .iter()
                .map(|&(start, len)| Ok(boxed_kv(FileRunKvStream::open(path, start, len)?)))
                .collect(),
        }
    }

    fn cleanup(self) {
        if let RunStoreKv::File { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Sort one run's pairs **stably** by key (duplicate keys keep their
/// arrival order, matching the rank-then-permute merge semantics).
fn sort_run(keys: &[u32], pays: &[u64]) -> (Vec<u32>, Vec<u64>) {
    let mut pairs: Vec<(u32, u64)> =
        keys.iter().copied().zip(pays.iter().copied()).collect();
    pairs.sort_by_key(|&(k, _)| k);
    (pairs.iter().map(|&(k, _)| k).collect(), pairs.iter().map(|&(_, p)| p).collect())
}

fn drain_to_vecs(
    mut tree: MergeTreeKv<'_>,
    keys: &mut Vec<u32>,
    pays: &mut Vec<u64>,
) -> Result<BlockKernelKv> {
    while tree.next_chunk(DRAIN, keys, pays)? > 0 {}
    Ok(tree.into_kernel())
}

/// One intermediate KV pass: merge groups of `max_fanin` runs into the
/// next generation (memory→memory or spill→spill).
fn merge_pass_kv(
    store: RunStoreKv,
    cfg: &ExtSortConfig,
    stats: &mut ExtSortStats,
    mut kernel: BlockKernelKv,
) -> Result<(RunStoreKv, BlockKernelKv)> {
    let count = store.count();
    let next = match &store {
        RunStoreKv::Mem(_) => {
            let mut runs = Vec::with_capacity(count.div_ceil(cfg.max_fanin));
            let mut lo = 0;
            while lo < count {
                let hi = (lo + cfg.max_fanin).min(count);
                let (mut rk, mut rp) = (Vec::new(), Vec::new());
                let tree = MergeTreeKv::with_kernel(store.open(lo, hi)?, kernel);
                kernel = drain_to_vecs(tree, &mut rk, &mut rp)?;
                runs.push((rk, rp));
                lo = hi;
            }
            RunStoreKv::Mem(runs)
        }
        RunStoreKv::File { path, .. } => {
            let dir = path.parent().map(Path::to_path_buf).unwrap_or_else(|| PathBuf::from("."));
            let mut w = SpillWriterKv::create(next_spill_path(&dir))?;
            let (mut ck, mut cp) = (Vec::with_capacity(DRAIN), Vec::with_capacity(DRAIN));
            let mut lo = 0;
            while lo < count {
                let hi = (lo + cfg.max_fanin).min(count);
                let mut tree = MergeTreeKv::with_kernel(store.open(lo, hi)?, kernel);
                w.begin_run();
                loop {
                    ck.clear();
                    cp.clear();
                    if tree.next_chunk(DRAIN, &mut ck, &mut cp)? == 0 {
                        break;
                    }
                    w.write_records(&ck, &cp)?;
                }
                w.end_run();
                kernel = tree.into_kernel();
                lo = hi;
            }
            let (path, runs) = w.finish()?;
            stats.spilled_runs += runs.len();
            stats.spill_bytes += runs.iter().map(|&(_, len)| len * REC_BYTES).sum::<u64>();
            RunStoreKv::File { path, runs }
        }
    };
    store.cleanup();
    Ok((next, kernel))
}

/// External key-value sort: form stable runs, optionally spill them as
/// 12-byte records, merge pass by pass through [`MergeTreeKv`], stream
/// the final k-way merge into owned columns. Each payload is moved by
/// I/O and the per-row permutation gather only — never by a
/// compare-exchange.
pub fn extsort_kv(
    keys: &[u32],
    pays: &[u64],
    cfg: &ExtSortConfig,
) -> Result<(Vec<u32>, Vec<u64>, ExtSortStats)> {
    anyhow::ensure!(keys.len() == pays.len(), "key/payload columns differ in length");
    anyhow::ensure!(cfg.run_len >= 1, "run_len must be >= 1");
    anyhow::ensure!(cfg.max_fanin >= 2, "max_fanin must be >= 2");
    let mut kernel = BlockKernelKv::new(cfg.r)?;
    let mut stats = ExtSortStats { keys: keys.len(), ..Default::default() };
    if keys.is_empty() {
        return Ok((Vec::new(), Vec::new(), stats));
    }
    let mut store = match &cfg.spill_dir {
        None => {
            let runs: Vec<(Vec<u32>, Vec<u64>)> = keys
                .chunks(cfg.run_len)
                .zip(pays.chunks(cfg.run_len))
                .map(|(ck, cp)| sort_run(ck, cp))
                .collect();
            RunStoreKv::Mem(runs)
        }
        Some(dir) => {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating spill dir {}", dir.display()))?;
            let mut w = SpillWriterKv::create(next_spill_path(dir))?;
            for (ck, cp) in keys.chunks(cfg.run_len).zip(pays.chunks(cfg.run_len)) {
                let (rk, rp) = sort_run(ck, cp);
                w.push_run(&rk, &rp)?;
            }
            let (path, runs) = w.finish()?;
            stats.spilled_runs += runs.len();
            stats.spill_bytes += REC_BYTES * keys.len() as u64;
            RunStoreKv::File { path, runs }
        }
    };
    stats.runs = store.count();
    while store.count() > cfg.max_fanin {
        (store, kernel) = merge_pass_kv(store, cfg, &mut stats, kernel)?;
        stats.merge_passes += 1;
    }
    let (mut out_k, mut out_p) =
        (Vec::with_capacity(keys.len()), Vec::with_capacity(keys.len()));
    drain_to_vecs(
        MergeTreeKv::with_kernel(store.open(0, store.count())?, kernel),
        &mut out_k,
        &mut out_p,
    )?;
    store.cleanup();
    Ok((out_k, out_p, stats))
}

/// Sort a file of 12-byte little-endian `(u32 key, u64 payload)`
/// records into `output` in bounded memory — the key-value twin of
/// [`super::extsort::extsort_file`]. Backs `loms sort --payload`.
pub fn extsort_kv_file(input: &Path, output: &Path, cfg: &ExtSortConfig) -> Result<ExtSortStats> {
    anyhow::ensure!(cfg.run_len >= 1, "run_len must be >= 1");
    anyhow::ensure!(cfg.max_fanin >= 2, "max_fanin must be >= 2");
    let mut kernel = BlockKernelKv::new(cfg.r)?;
    let bytes = std::fs::metadata(input)
        .with_context(|| format!("stat {}", input.display()))?
        .len();
    anyhow::ensure!(
        bytes % REC_BYTES == 0,
        "{}: not a whole number of 12-byte key-value records",
        input.display()
    );
    let total = bytes / REC_BYTES;
    let mut stats = ExtSortStats { keys: total as usize, ..Default::default() };
    let dir = cfg
        .spill_dir
        .clone()
        .or_else(|| output.parent().map(Path::to_path_buf).filter(|p| !p.as_os_str().is_empty()))
        .unwrap_or_else(|| PathBuf::from("."));
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating spill dir {}", dir.display()))?;
    // Phase 1: read run_len-record windows, stable-sort, spill.
    let mut store = {
        let mut rd = BufReader::new(
            File::open(input).with_context(|| format!("opening {}", input.display()))?,
        );
        let mut w = SpillWriterKv::create(next_spill_path(&dir))?;
        let mut buf = vec![0u8; cfg.run_len * REC_BYTES as usize];
        let mut remaining = total;
        while remaining > 0 {
            let n = (cfg.run_len as u64).min(remaining) as usize;
            rd.read_exact(&mut buf[..n * REC_BYTES as usize]).context("reading input records")?;
            let (mut ck, mut cp) = (Vec::with_capacity(n), Vec::with_capacity(n));
            for rec in buf[..n * REC_BYTES as usize].chunks_exact(REC_BYTES as usize) {
                ck.push(u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]));
                cp.push(u64::from_le_bytes([
                    rec[4], rec[5], rec[6], rec[7], rec[8], rec[9], rec[10], rec[11],
                ]));
            }
            let (rk, rp) = sort_run(&ck, &cp);
            w.push_run(&rk, &rp)?;
            remaining -= n as u64;
        }
        let (path, runs) = w.finish()?;
        stats.spilled_runs += runs.len();
        stats.spill_bytes += bytes;
        RunStoreKv::File { path, runs }
    };
    stats.runs = store.count();
    while store.count() > cfg.max_fanin {
        (store, kernel) = merge_pass_kv(store, cfg, &mut stats, kernel)?;
        stats.merge_passes += 1;
    }
    // Phase 3: stream the final merge straight into the output file.
    {
        let mut w = BufWriter::new(
            File::create(output).with_context(|| format!("creating {}", output.display()))?,
        );
        let mut tree = MergeTreeKv::with_kernel(store.open(0, store.count())?, kernel);
        let (mut ck, mut cp) = (Vec::with_capacity(DRAIN), Vec::with_capacity(DRAIN));
        let mut out_bytes = Vec::new();
        loop {
            ck.clear();
            cp.clear();
            if tree.next_chunk(DRAIN, &mut ck, &mut cp)? == 0 {
                break;
            }
            encode_records(&ck, &cp, &mut out_bytes);
            w.write_all(&out_bytes)?;
        }
        w.flush()?;
    }
    store.cleanup();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Full-discrimination oracle: merged keys equal the sorted key
    /// concat AND the (key, payload) pair multiset is preserved — with
    /// globally unique payloads this proves every duplicate key carried
    /// exactly the payload it arrived with.
    fn check_kv(got_k: &[u32], got_p: &[u64], inputs: &[(Vec<u32>, Vec<u64>)]) {
        let mut want_k: Vec<u32> =
            inputs.iter().flat_map(|(k, _)| k.iter().copied()).collect();
        want_k.sort_unstable();
        assert_eq!(got_k, want_k.as_slice(), "merged keys");
        assert_eq!(got_k.len(), got_p.len(), "column widths");
        let mut got_pairs: Vec<(u32, u64)> =
            got_k.iter().copied().zip(got_p.iter().copied()).collect();
        let mut want_pairs: Vec<(u32, u64)> = inputs
            .iter()
            .flat_map(|(k, p)| k.iter().copied().zip(p.iter().copied()))
            .collect();
        got_pairs.sort_unstable();
        want_pairs.sort_unstable();
        assert_eq!(got_pairs, want_pairs, "(key, payload) pair multiset");
    }

    /// Random sorted keys with globally unique payload tags.
    fn tagged_run(rng: &mut Rng, len: usize, max: u32, tag: u64) -> (Vec<u32>, Vec<u64>) {
        let keys = rng.sorted_list(len, max);
        let pays = (0..keys.len() as u64).map(|i| (tag << 32) | i).collect();
        (keys, pays)
    }

    #[test]
    fn kernel_merges_pairs_with_payloads_intact() {
        let mut kern = BlockKernelKv::new(8).unwrap();
        assert_eq!(kern.r(), 8);
        assert!(kern.device_name().contains("loms"));
        let mut rng = Rng::new(0x1257);
        for case in 0..40 {
            // Duplicate-heavy small key domain every few cases.
            let max = if case % 3 == 0 { 6 } else { 1 << 20 };
            let (ak, ap) = tagged_run(&mut rng, rng.range(0, 9), max, 1);
            let (bk, bp) = tagged_run(&mut rng, rng.range(0, 9), max, 2);
            let lists = [ak.clone(), bk.clone()];
            let width = ak.len() + bk.len();
            let mut out_k = vec![0u32; width];
            let mut out_p = vec![0u64; width];
            kern.merge_rows(
                &[&lists],
                &[[&ap, &bp]],
                &mut [&mut out_k[..]],
                &mut [&mut out_p[..]],
            );
            check_kv(&out_k, &out_p, &[(ak.clone(), ap.clone()), (bk.clone(), bp.clone())]);
            // The node merge is stable: equal keys emit list 0 first,
            // each list in arrival order — exactly a stable sort of the
            // zipped concat.
            let mut pairs: Vec<(u32, u64)> = ak
                .iter()
                .copied()
                .zip(ap.iter().copied())
                .chain(bk.iter().copied().zip(bp.iter().copied()))
                .collect();
            pairs.sort_by_key(|&(k, _)| k);
            let want_p: Vec<u64> = pairs.iter().map(|&(_, p)| p).collect();
            assert_eq!(out_p, want_p, "case {case}: stable payload order");
        }
    }

    #[test]
    fn kernel_batches_independent_rows() {
        let mut kern = BlockKernelKv::new(4).unwrap();
        let mut rng = Rng::new(0xBA7D);
        let n_rows = crate::sortnet::lanes::LANES + 5;
        let pairs: Vec<[(Vec<u32>, Vec<u64>); 2]> = (0..n_rows)
            .map(|i| {
                [
                    tagged_run(&mut rng, rng.range(0, 5), 100, 2 * i as u64),
                    tagged_run(&mut rng, rng.range(1, 5), 100, 2 * i as u64 + 1),
                ]
            })
            .collect();
        let key_rows: Vec<[Vec<u32>; 2]> =
            pairs.iter().map(|p| [p[0].0.clone(), p[1].0.clone()]).collect();
        let rows: Vec<&[Vec<u32>]> = key_rows.iter().map(|p| &p[..]).collect();
        let pay_rows: Vec<[&[u64]; 2]> =
            pairs.iter().map(|p| [p[0].1.as_slice(), p[1].1.as_slice()]).collect();
        let widths: Vec<usize> = pairs.iter().map(|p| p[0].0.len() + p[1].0.len()).collect();
        let mut out_k: Vec<Vec<u32>> = widths.iter().map(|&w| vec![0u32; w]).collect();
        let mut out_p: Vec<Vec<u64>> = widths.iter().map(|&w| vec![0u64; w]).collect();
        let mut key_outs: Vec<&mut [u32]> = out_k.iter_mut().map(|v| v.as_mut_slice()).collect();
        let mut pay_outs: Vec<&mut [u64]> = out_p.iter_mut().map(|v| v.as_mut_slice()).collect();
        kern.merge_rows(&rows, &pay_rows, &mut key_outs, &mut pay_outs);
        for (i, p) in pairs.iter().enumerate() {
            check_kv(&out_k[i], &out_p[i], &[p[0].clone(), p[1].clone()]);
        }
    }

    #[test]
    fn max_value_keys_are_legal() {
        // Unlike the serving path, u32::MAX is a legal stream key: it
        // packs below the u64::MAX pad because origins stay small.
        let mut kern = BlockKernelKv::new(4).unwrap();
        let ak = vec![1, u32::MAX - 1, u32::MAX];
        let ap = vec![10, 11, 12];
        let bk = vec![u32::MAX - 1, u32::MAX];
        let bp = vec![20, 21];
        let lists = [ak.clone(), bk.clone()];
        let mut out_k = vec![0u32; 5];
        let mut out_p = vec![0u64; 5];
        kern.merge_rows(&[&lists], &[[&ap, &bp]], &mut [&mut out_k[..]], &mut [&mut out_p[..]]);
        assert_eq!(out_k, vec![1, u32::MAX - 1, u32::MAX - 1, u32::MAX, u32::MAX]);
        assert_eq!(out_p, vec![10, 11, 20, 12, 21]);
    }

    #[test]
    fn merge_runs_matches_oracle_across_k_and_r() {
        let mut rng = Rng::new(0x7EF);
        for &k in &[2usize, 3, 5, 8, 17] {
            for &r in &[2usize, 8, 32] {
                let runs: Vec<(Vec<u32>, Vec<u64>)> = (0..k)
                    .map(|i| tagged_run(&mut rng, rng.range(0, 300), 5000, i as u64))
                    .collect();
                let (gk, gp) = merge_runs_kv(&runs, r).unwrap();
                check_kv(&gk, &gp, &runs);
            }
        }
    }

    #[test]
    fn degenerate_k() {
        let (k0, p0) = merge_k_kv(vec![], 8).unwrap();
        assert!(k0.is_empty() && p0.is_empty());
        let one: Vec<Box<dyn SortedKvStream>> =
            vec![boxed_kv(VecKvStream::new(vec![3, 4, 5], vec![30, 40, 50]))];
        assert_eq!(merge_k_kv(one, 8).unwrap(), (vec![3, 4, 5], vec![30, 40, 50]));
        let runs = vec![(vec![], vec![]), (vec![], vec![])];
        let (k2, p2) = merge_runs_kv(&runs, 8).unwrap();
        assert!(k2.is_empty() && p2.is_empty());
    }

    #[test]
    fn trees_compose_as_streams() {
        let mut rng = Rng::new(0xC1);
        let inner_runs: Vec<(Vec<u32>, Vec<u64>)> =
            (0..3).map(|i| tagged_run(&mut rng, 100, 1000, i as u64)).collect();
        let outer_run = tagged_run(&mut rng, 150, 1000, 99);
        let inner_streams: Vec<Box<dyn SortedKvStream + '_>> = inner_runs
            .iter()
            .map(|(k, p)| boxed_kv(SliceKvStream::new(k, p)))
            .collect();
        let inner = MergeTreeKv::new(inner_streams, 8).unwrap();
        let outer: Vec<Box<dyn SortedKvStream + '_>> = vec![
            boxed_kv(inner),
            boxed_kv(SliceKvStream::new(&outer_run.0, &outer_run.1)),
        ];
        let (gk, gp) = merge_k_kv(outer, 8).unwrap();
        let mut all = inner_runs;
        all.push(outer_run);
        check_kv(&gk, &gp, &all);
    }

    #[test]
    fn stats_count_batched_rows() {
        let mut rng = Rng::new(0x91);
        let runs: Vec<(Vec<u32>, Vec<u64>)> =
            (0..17).map(|i| tagged_run(&mut rng, 500, 1 << 20, i as u64)).collect();
        let streams: Vec<Box<dyn SortedKvStream + '_>> = runs
            .iter()
            .map(|(k, p)| boxed_kv(SliceKvStream::new(k, p)))
            .collect();
        let mut tree = MergeTreeKv::new(streams, 8).unwrap();
        let (mut gk, mut gp) = (Vec::new(), Vec::new());
        while tree.next_chunk(DRAIN, &mut gk, &mut gp).unwrap() > 0 {}
        check_kv(&gk, &gp, &runs);
        let st = tree.stats();
        assert!(st.kernel_rows > st.kernel_batches, "rounds batch multiple nodes: {st:?}");
        assert_eq!(st.flushes, 16, "every internal node flushes once");
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("loms_kvsort_{tag}_{}", std::process::id()))
    }

    #[test]
    fn in_memory_kv_sort_matches_stable_std() {
        let mut rng = Rng::new(0xE6);
        let keys: Vec<u32> = (0..10_000).map(|_| rng.next_u32() % 997).collect();
        let pays: Vec<u64> = (0..keys.len() as u64).collect();
        let cfg = ExtSortConfig { run_len: 700, r: 8, ..Default::default() };
        let (gk, gp, stats) = extsort_kv(&keys, &pays, &cfg).unwrap();
        check_kv(&gk, &gp, &[(keys, pays)]);
        assert_eq!(stats.runs, 10_000usize.div_ceil(700));
        assert_eq!(stats.merge_passes, 0);
        assert_eq!(stats.spilled_runs, 0);
        assert_eq!(gp.len(), gk.len());
    }

    #[test]
    fn multi_pass_spill_kv_sort_round_trips() {
        let dir = tmp_dir("multipass");
        let mut rng = Rng::new(0x5112);
        let mut keys: Vec<u32> = (0..20_000).map(|_| rng.next_u32()).collect();
        keys.extend([u32::MAX, u32::MAX - 1, u32::MAX]); // full domain legal
        let pays: Vec<u64> = (0..keys.len() as u64).map(|i| i ^ 0xDEAD_BEEF).collect();
        let cfg = ExtSortConfig {
            run_len: 512,
            r: 8,
            max_fanin: 3,
            spill_dir: Some(dir.clone()),
        };
        let (gk, gp, stats) = extsort_kv(&keys, &pays, &cfg).unwrap();
        check_kv(&gk, &gp, &[(keys, pays)]);
        assert!(stats.merge_passes >= 2, "fanin 3 over {} runs: {stats:?}", stats.runs);
        assert!(stats.spilled_runs > stats.runs, "intermediate runs spilled too");
        assert!(stats.spill_bytes > 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn file_to_file_kv_round_trip() {
        let dir = tmp_dir("file");
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("input.kv12");
        let output = dir.join("sorted.kv12");
        let mut rng = Rng::new(0xF17F);
        let keys: Vec<u32> = (0..5_000).map(|_| rng.next_u32() % 4099).collect();
        let pays: Vec<u64> = (0..keys.len() as u64).collect();
        let mut bytes = Vec::new();
        encode_records(&keys, &pays, &mut bytes);
        std::fs::write(&input, &bytes).unwrap();
        let cfg = ExtSortConfig {
            run_len: 333,
            r: 8,
            max_fanin: 4,
            spill_dir: Some(dir.clone()),
        };
        let stats = extsort_kv_file(&input, &output, &cfg).unwrap();
        assert_eq!(stats.keys, keys.len());
        assert!(stats.merge_passes >= 1);
        let out = std::fs::read(&output).unwrap();
        let (mut gk, mut gp) = (Vec::new(), Vec::new());
        for rec in out.chunks_exact(12) {
            gk.push(u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]));
            gp.push(u64::from_le_bytes([
                rec[4], rec[5], rec[6], rec[7], rec[8], rec[9], rec[10], rec[11],
            ]));
        }
        check_kv(&gk, &gp, &[(keys, pays)]);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn file_run_kv_stream_reads_its_window() {
        let dir = tmp_dir("window");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("runs.kv12");
        let keys: Vec<u32> = (0..50).map(|x| x * 3).collect();
        let pays: Vec<u64> = (0..50).map(|x| x * 7).collect();
        let mut bytes = Vec::new();
        encode_records(&keys, &pays, &mut bytes);
        std::fs::write(&path, &bytes).unwrap();
        let mut a = FileRunKvStream::open(&path, 0, 20).unwrap();
        let mut b = FileRunKvStream::open(&path, 20, 30).unwrap();
        let (mut ak, mut ap) = (Vec::new(), Vec::new());
        while a.next_chunk(7, &mut ak, &mut ap).unwrap() > 0 {}
        assert_eq!(ak, keys[..20]);
        assert_eq!(ap, pays[..20]);
        let (mut bk, mut bp) = (Vec::new(), Vec::new());
        while b.next_chunk(9, &mut bk, &mut bp).unwrap() > 0 {}
        assert_eq!(bk, keys[20..]);
        assert_eq!(bp, pays[20..]);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn degenerate_sorts() {
        let cfg = ExtSortConfig { r: 4, ..Default::default() };
        let (k, p, _) = extsort_kv(&[], &[], &cfg).unwrap();
        assert!(k.is_empty() && p.is_empty());
        let (k, p, _) = extsort_kv(&[9], &[90], &cfg).unwrap();
        assert_eq!((k, p), (vec![9], vec![90]));
        // Mismatched columns rejected up front.
        assert!(extsort_kv(&[1, 2], &[1], &cfg).is_err());
    }
}
