//! Key-value streaming merge: the [`super::merge2`] / [`super::tree`] /
//! [`super::extsort`] engine with a `u64` payload riding beside every
//! key — payloads never enter a compare-exchange.
//!
//! The kernel is the **rank-then-permute** lowering
//! ([`crate::sortnet::lanes::LanePlan::run_view_batch_perm_into`]): keys
//! packed with list-major origin ranks run through the unmodified CAS
//! stream, and the emitted permutation gathers each payload column once
//! per row. Everything above the kernel — the FLiMS emit/retain
//! arithmetic, the children-first tree scheduler, run formation and
//! spill passes — is the key-only engine with a payload vector carried
//! in lock-step beside every key buffer.
//!
//! Like the key-only stream engine (and unlike the serving path), fill
//! is tracked by count, so the full `u32` key domain is legal: a real
//! `u32::MAX` key packs below the `u64::MAX` pad because its origin
//! rank stays far below `u32::MAX`.
//!
//! Spill format: back-to-back 12-byte little-endian records, `u32` key
//! then `u64` payload ([`FileRunKvStream`]).

use crate::sortnet::lanes::{self, LanePlan, LaneScratch};
use crate::sortnet::loms;
use crate::sortnet::plan::CompiledPlan;
use anyhow::{anyhow, bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::extsort::{ExtSortConfig, ExtSortStats, SpillSeg};
use super::io::{
    self, decode_records_into, encode_records_into, pipeline, sidecar_path, spill_io,
    FilePrefetch, IoPhase, IoWait, SpillChecksum, SpillGuard, SpillReader, WriteBehind,
};
use super::part::{self, FileCutter};
use super::tree::TreeStats;
use crate::util::fault::{self, Site};

/// Record pairs pulled from the merge tree per drain step.
const DRAIN: usize = 4096;

/// Bytes per spilled `(key, payload)` record.
const REC_BYTES: u64 = 12;

/// A stream of ascending `u32` keys with one `u64` payload each, pulled
/// in bounded chunks. Same contract as [`super::source::SortedStream`]:
/// keys ascending across the whole stream (duplicates allowed, payloads
/// ride with their key), `next_chunk` appends at most `max` pairs to
/// `keys`/`pays` in lock-step and returns the count; `0` means
/// exhausted, never transient.
pub trait SortedKvStream {
    fn next_chunk(&mut self, max: usize, keys: &mut Vec<u32>, pays: &mut Vec<u64>)
        -> Result<usize>;
}

/// Box an adapter for [`MergeTreeKv`]'s input list.
pub fn boxed_kv<'a>(s: impl SortedKvStream + 'a) -> Box<dyn SortedKvStream + 'a> {
    Box::new(s)
}

/// Borrowed sorted key/payload columns as a stream.
#[derive(Debug)]
pub struct SliceKvStream<'a> {
    keys: &'a [u32],
    pays: &'a [u64],
    pos: usize,
}

impl<'a> SliceKvStream<'a> {
    pub fn new(keys: &'a [u32], pays: &'a [u64]) -> Self {
        assert_eq!(keys.len(), pays.len(), "key/payload columns differ in length");
        debug_assert!(keys.windows(2).all(|w| w[0] <= w[1]), "keys not sorted");
        SliceKvStream { keys, pays, pos: 0 }
    }
}

impl SortedKvStream for SliceKvStream<'_> {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        let n = max.min(self.keys.len() - self.pos);
        keys.extend_from_slice(&self.keys[self.pos..self.pos + n]);
        pays.extend_from_slice(&self.pays[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Owned sorted key/payload columns as a stream.
#[derive(Debug)]
pub struct VecKvStream {
    keys: Vec<u32>,
    pays: Vec<u64>,
    pos: usize,
}

impl VecKvStream {
    pub fn new(keys: Vec<u32>, pays: Vec<u64>) -> Self {
        assert_eq!(keys.len(), pays.len(), "key/payload columns differ in length");
        debug_assert!(keys.windows(2).all(|w| w[0] <= w[1]), "keys not sorted");
        VecKvStream { keys, pays, pos: 0 }
    }
}

impl SortedKvStream for VecKvStream {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        let n = max.min(self.keys.len() - self.pos);
        keys.extend_from_slice(&self.keys[self.pos..self.pos + n]);
        pays.extend_from_slice(&self.pays[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// One sorted run inside a file of 12-byte little-endian `(u32 key,
/// u64 payload)` records — the key-value spill format. Mirrors
/// [`super::source::FileRunStream`]: one seek at open, sequential reads
/// after, each run stream owning its handle.
#[derive(Debug)]
pub struct FileRunKvStream {
    file: File,
    /// Records left to read.
    remaining: u64,
    /// Reusable byte buffer for bulk reads.
    buf: Vec<u8>,
}

impl FileRunKvStream {
    /// Open the run spanning records `[start, start + records)` of `path`.
    pub fn open(path: &Path, start: u64, records: u64) -> Result<Self> {
        let mut file =
            File::open(path).with_context(|| format!("opening KV run file {}", path.display()))?;
        file.seek(SeekFrom::Start(start * REC_BYTES))
            .with_context(|| format!("seeking KV run at record {start} in {}", path.display()))?;
        Ok(FileRunKvStream { file, remaining: records, buf: Vec::new() })
    }
}

impl SortedKvStream for FileRunKvStream {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        let n = (max as u64).min(self.remaining) as usize;
        if n == 0 {
            return Ok(0);
        }
        self.buf.resize(n * REC_BYTES as usize, 0);
        self.file.read_exact(&mut self.buf).context("reading KV spill run")?;
        decode_records_into(&self.buf, keys, pays);
        self.remaining -= n as u64;
        Ok(n)
    }
}

/// [`FileRunKvStream`] with a dedicated read-ahead thread: buffer B
/// fills while the merge tree drains buffer A ([`FilePrefetch`]), so
/// the tree never blocks on a cold read. Buffers hold whole records.
pub struct PrefetchRunKvStream {
    fetch: FilePrefetch,
    buf: Vec<u8>,
    pos: usize,
}

impl PrefetchRunKvStream {
    /// Read ahead over records `[start, start + records)` of `path`,
    /// `buf_records` records per buffer.
    pub fn open(
        path: &Path,
        start: u64,
        records: u64,
        buf_records: usize,
        wait: IoWait,
    ) -> Result<Self> {
        let buf_bytes = buf_records.max(1) * REC_BYTES as usize;
        let fetch =
            FilePrefetch::spawn(path, start * REC_BYTES, records * REC_BYTES, buf_bytes, wait)?;
        Ok(PrefetchRunKvStream { fetch, buf: Vec::new(), pos: 0 })
    }
}

impl SortedKvStream for PrefetchRunKvStream {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        if self.pos == self.buf.len() {
            match self.fetch.next_buf()? {
                Some(b) => {
                    self.buf = b;
                    self.pos = 0;
                }
                None => return Ok(0),
            }
        }
        let rec = REC_BYTES as usize;
        let n = max.min((self.buf.len() - self.pos) / rec);
        decode_records_into(&self.buf[self.pos..self.pos + n * rec], keys, pays);
        self.pos += n * rec;
        Ok(n)
    }
}

/// A KV spill run read through the checksum-verifying
/// [`SpillReader`] — same byte layout and delivered records as
/// [`FileRunKvStream`]/[`PrefetchRunKvStream`], but every checksum
/// block is validated against the segment's `.crc` sidecar (bounded
/// re-read recovery, typed [`super::io::ExtSortError`] on
/// unrecoverable corruption).
pub struct SpillRunKvStream {
    rd: SpillReader,
    carry_k: Vec<u32>,
    carry_p: Vec<u64>,
    pos: usize,
}

impl SpillRunKvStream {
    /// Verified reads over records `[start, start + records)` of
    /// `path`. `prefetch_records == 0` selects synchronous block reads.
    pub fn open(
        path: &Path,
        start: u64,
        records: u64,
        prefetch_records: usize,
        wait: IoWait,
    ) -> Result<Self> {
        let rd =
            SpillReader::open(path, start, records, REC_BYTES as usize, prefetch_records, wait)?;
        Ok(SpillRunKvStream { rd, carry_k: Vec::new(), carry_p: Vec::new(), pos: 0 })
    }
}

impl SortedKvStream for SpillRunKvStream {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        while self.pos == self.carry_k.len() {
            self.carry_k.clear();
            self.carry_p.clear();
            self.pos = 0;
            match self.rd.next_verified()? {
                Some(bytes) if !bytes.is_empty() => {
                    decode_records_into(bytes, &mut self.carry_k, &mut self.carry_p)
                }
                Some(_) => continue,
                None => return Ok(0),
            }
        }
        let n = max.min(self.carry_k.len() - self.pos);
        keys.extend_from_slice(&self.carry_k[self.pos..self.pos + n]);
        pays.extend_from_slice(&self.carry_p[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// The compiled `loms2` R+R kernel on the rank-then-permute path:
/// scalar plan, lane plan, the packed `u64` tile scratch, and the
/// reusable flat permutation buffer the payload gather reads through.
pub struct BlockKernelKv {
    r: usize,
    plan: CompiledPlan,
    lane: LanePlan,
    scratch: LaneScratch<u64>,
    perm_buf: Vec<u32>,
}

impl BlockKernelKv {
    /// Compile the `loms_2way(r, r, 2)` device — the same device the
    /// key-only [`super::merge2::BlockKernel`] runs; only the lowering
    /// differs (packed keys + permutation output).
    pub fn new(r: usize) -> Result<Self> {
        anyhow::ensure!(r >= 1, "block size R must be >= 1");
        let d = loms::loms_2way(r, r, 2);
        let plan = CompiledPlan::compile_auto(&d).map_err(|e| anyhow!("{}: {e}", d.name))?;
        let lane = LanePlan::compile(&plan);
        Ok(BlockKernelKv { r, plan, lane, scratch: LaneScratch::new(), perm_buf: Vec::new() })
    }

    /// Block size R.
    pub fn r(&self) -> usize {
        self.r
    }

    /// Compiled device name (diagnostics / stats).
    pub fn device_name(&self) -> &str {
        &self.plan.name
    }

    /// Execute one batch of independent node steps. `rows[i]` is a
    /// node's `[high, block]` key pair; `pay_rows[i]` the matching
    /// payload pair; `out_keys[i]` / `out_pays[i]` are the equal-width
    /// (`h_i + m_i`) destinations. Keys run through the packed
    /// comparator tiles; each payload moves exactly once, gathered
    /// through the emitted permutation.
    pub fn merge_rows(
        &mut self,
        rows: &[&[Vec<u32>]],
        pay_rows: &[[&[u64]; 2]],
        out_keys: &mut [&mut [u32]],
        out_pays: &mut [&mut [u64]],
    ) {
        debug_assert_eq!(rows.len(), pay_rows.len());
        debug_assert_eq!(rows.len(), out_pays.len());
        let BlockKernelKv { plan, lane, scratch, perm_buf, .. } = self;
        // Split one flat reusable buffer into per-row permutation slices.
        let total: usize = out_keys.iter().map(|o| o.len()).sum();
        perm_buf.clear();
        perm_buf.resize(total, 0);
        let mut perm_outs: Vec<&mut [u32]> = Vec::with_capacity(rows.len());
        let mut rest = perm_buf.as_mut_slice();
        for o in out_keys.iter() {
            let (head, tail) = rest.split_at_mut(o.len());
            perm_outs.push(head);
            rest = tail;
        }
        lanes::run_view_batch_perm_auto(lane, plan, rows, scratch, out_keys, &mut perm_outs)
            .expect("fast-mode perm execution is infallible on sorted blocks");
        // The single payload move: origin ranks index the row's
        // list-major concatenation `[high, block]`.
        for (i, perm) in perm_outs.iter().enumerate() {
            let [p0, p1] = pay_rows[i];
            let dst = &mut *out_pays[i];
            for (t, &p) in perm.iter().enumerate() {
                let p = p as usize;
                dst[t] = if p < p0.len() { p0[p] } else { p1[p - p0.len()] };
            }
        }
    }
}

/// One streaming 2-way key-value merge node: [`super::merge2::BlockMerger2`]
/// with a payload vector in lock-step beside each key buffer. The
/// emit/retain arithmetic ([`Self::emit_count`]) reads keys only — its
/// safety proof is unchanged — and [`Self::apply`] moves the merged
/// payload column alongside the merged keys.
#[derive(Debug, Default)]
pub struct BlockMerger2Kv {
    /// `lists[0]` = high buffer, `lists[1]` = staged block — the
    /// kernel's two key slots.
    lists: [Vec<u32>; 2],
    /// Payload columns in lock-step with `lists`.
    pays: [Vec<u64>; 2],
}

impl BlockMerger2Kv {
    pub fn new() -> Self {
        BlockMerger2Kv::default()
    }

    /// The retained high-buffer keys.
    pub fn high(&self) -> &[u32] {
        &self.lists[0]
    }

    /// The kernel key-row view (`[high, block]`).
    pub fn lists(&self) -> &[Vec<u32>] {
        &self.lists
    }

    /// The kernel payload-row view (`[high, block]`).
    pub fn pay_slices(&self) -> [&[u64]; 2] {
        [&self.pays[0], &self.pays[1]]
    }

    /// Clear and return the staging buffers for the next block; the
    /// caller fills both in lock-step with up to R pairs.
    pub fn stage_bufs(&mut self) -> (&mut Vec<u32>, &mut Vec<u64>) {
        self.lists[1].clear();
        self.pays[1].clear();
        (&mut self.lists[1], &mut self.pays[1])
    }

    /// Pairs in flight (`h + m`) — the kernel output width for this row.
    pub fn width(&self) -> usize {
        self.lists[0].len() + self.lists[1].len()
    }

    /// How many merged pairs may be emitted this step — identical to
    /// [`super::merge2::BlockMerger2::emit_count`]: the bound depends
    /// only on key order, so the payload column cannot change it.
    pub fn emit_count(&self, other_head: Option<u32>) -> usize {
        let h = self.lists[0].len();
        let m = self.lists[1].len();
        let cnt = match other_head {
            None => m,
            Some(v) => self.lists[1].partition_point(|&x| x <= v),
        };
        m.min(h + cnt)
    }

    /// Consume one kernel output: the low cones of both columns are
    /// appended to `emit_k`/`emit_p`, the high cones become the new
    /// high buffers, the staged block is cleared.
    pub fn apply(
        &mut self,
        merged_keys: &[u32],
        merged_pays: &[u64],
        k: usize,
        emit_k: &mut Vec<u32>,
        emit_p: &mut Vec<u64>,
    ) {
        debug_assert_eq!(merged_keys.len(), self.width());
        debug_assert_eq!(merged_pays.len(), merged_keys.len());
        debug_assert!(k <= merged_keys.len());
        emit_k.extend_from_slice(&merged_keys[..k]);
        emit_p.extend_from_slice(&merged_pays[..k]);
        self.lists[0].clear();
        self.lists[0].extend_from_slice(&merged_keys[k..]);
        self.pays[0].clear();
        self.pays[0].extend_from_slice(&merged_pays[k..]);
        self.lists[1].clear();
        self.pays[1].clear();
    }

    /// Endgame: both inputs exhausted and empty — the high buffers are
    /// the sorted remainder.
    pub fn flush(&mut self, emit_k: &mut Vec<u32>, emit_p: &mut Vec<u64>) {
        debug_assert!(self.lists[1].is_empty(), "flush with a staged block");
        emit_k.append(&mut self.lists[0]);
        emit_p.append(&mut self.pays[0]);
    }
}

/// Where a node (or the root) pulls pairs from.
#[derive(Debug, Clone, Copy)]
enum Input {
    Leaf(usize),
    Node(usize),
}

/// What an input looks like at staging time.
#[derive(Debug, Clone, Copy)]
enum Peek {
    Key(u32),
    Exhausted,
    Pending,
}

/// A leaf: one input stream plus a ≤ R-pair pull buffer.
struct LeafKvSource<'a> {
    stream: Box<dyn SortedKvStream + 'a>,
    keys: Vec<u32>,
    pays: Vec<u64>,
    pos: usize,
    done: bool,
}

impl LeafKvSource<'_> {
    fn avail(&self) -> usize {
        self.keys.len() - self.pos
    }

    fn fill_to(&mut self, want: usize) -> Result<()> {
        if self.done || self.avail() >= want {
            return Ok(());
        }
        if self.pos > 0 {
            self.keys.drain(..self.pos);
            self.pays.drain(..self.pos);
            self.pos = 0;
        }
        while self.keys.len() < want {
            let got =
                self.stream.next_chunk(want - self.keys.len(), &mut self.keys, &mut self.pays)?;
            if got == 0 {
                self.done = true;
                break;
            }
        }
        Ok(())
    }

    fn head(&mut self) -> Result<Option<u32>> {
        self.fill_to(1)?;
        Ok(self.keys.get(self.pos).copied())
    }

    fn take(&mut self, max: usize, dst_k: &mut Vec<u32>, dst_p: &mut Vec<u64>) -> Result<usize> {
        self.fill_to(max)?;
        let n = max.min(self.avail());
        dst_k.extend_from_slice(&self.keys[self.pos..self.pos + n]);
        dst_p.extend_from_slice(&self.pays[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// One internal merge node: the KV block merger plus its bounded output
/// FIFO (capacity 2R pairs, same deadlock-freedom argument as
/// [`super::tree`]).
struct NodeKv {
    left: Input,
    right: Input,
    merger: BlockMerger2Kv,
    out_k: Vec<u32>,
    out_p: Vec<u64>,
    start: usize,
    done: bool,
}

impl NodeKv {
    fn avail(&self) -> usize {
        self.out_k.len() - self.start
    }

    fn head(&self) -> Option<u32> {
        self.out_k.get(self.start).copied()
    }

    fn compact(&mut self) {
        if self.start > 0 {
            self.out_k.drain(..self.start);
            self.out_p.drain(..self.start);
            self.start = 0;
        }
    }

    fn take(&mut self, max: usize, dst_k: &mut Vec<u32>, dst_p: &mut Vec<u64>) -> usize {
        let n = max.min(self.avail());
        dst_k.extend_from_slice(&self.out_k[self.start..self.start + n]);
        dst_p.extend_from_slice(&self.out_p[self.start..self.start + n]);
        self.start += n;
        if self.start == self.out_k.len() {
            self.out_k.clear();
            self.out_p.clear();
            self.start = 0;
        }
        n
    }
}

/// One staged node step, recorded between staging and apply.
struct Staged {
    node: usize,
    k: usize,
    width: usize,
}

/// A k-way streaming key-value merge: [`SortedKvStream`] in,
/// [`SortedKvStream`] out, O(k·R) resident pairs. The scheduler is
/// [`super::tree::MergeTree`]'s, verbatim — children-first scan, refill
/// rule with ties to the left, one ragged kernel batch per round — over
/// the rank-then-permute kernel.
pub struct MergeTreeKv<'a> {
    r: usize,
    kernel: BlockKernelKv,
    leaves: Vec<LeafKvSource<'a>>,
    nodes: Vec<NodeKv>,
    root: Option<Input>,
    staged: Vec<Staged>,
    round_out_k: Vec<Vec<u32>>,
    round_out_p: Vec<Vec<u64>>,
    stats: TreeStats,
}

/// Balanced binary tree over `leaves[lo..hi)`, children pushed before
/// parents so an index-order scan is children-first.
fn build(lo: usize, hi: usize, nodes: &mut Vec<NodeKv>) -> Input {
    if hi - lo == 1 {
        return Input::Leaf(lo);
    }
    let mid = lo + (hi - lo) / 2;
    let left = build(lo, mid, nodes);
    let right = build(mid, hi, nodes);
    nodes.push(NodeKv {
        left,
        right,
        merger: BlockMerger2Kv::new(),
        out_k: Vec::new(),
        out_p: Vec::new(),
        start: 0,
        done: false,
    });
    Input::Node(nodes.len() - 1)
}

fn peek_input(nodes: &[NodeKv], leaves: &mut [LeafKvSource<'_>], inp: Input) -> Result<Peek> {
    Ok(match inp {
        Input::Leaf(l) => match leaves[l].head()? {
            Some(x) => Peek::Key(x),
            None => Peek::Exhausted,
        },
        Input::Node(c) => match nodes[c].head() {
            Some(x) => Peek::Key(x),
            None if nodes[c].done => Peek::Exhausted,
            None => Peek::Pending,
        },
    })
}

impl<'a> MergeTreeKv<'a> {
    /// Build a merge tree over `streams` with block size `r`. `k = 0`
    /// yields an empty stream; `k = 1` passes the single input through.
    pub fn new(streams: Vec<Box<dyn SortedKvStream + 'a>>, r: usize) -> Result<MergeTreeKv<'a>> {
        Ok(Self::with_kernel(streams, BlockKernelKv::new(r)?))
    }

    /// Build a tree around an already-compiled kernel (sequential trees
    /// of the same R hand it from tree to tree via [`Self::into_kernel`]).
    pub fn with_kernel(
        streams: Vec<Box<dyn SortedKvStream + 'a>>,
        kernel: BlockKernelKv,
    ) -> MergeTreeKv<'a> {
        let leaves: Vec<LeafKvSource<'a>> = streams
            .into_iter()
            .map(|s| LeafKvSource {
                stream: s,
                keys: Vec::new(),
                pays: Vec::new(),
                pos: 0,
                done: false,
            })
            .collect();
        let mut nodes = Vec::new();
        let root = match leaves.len() {
            0 => None,
            n => Some(build(0, n, &mut nodes)),
        };
        MergeTreeKv {
            r: kernel.r(),
            kernel,
            leaves,
            nodes,
            root,
            staged: Vec::new(),
            round_out_k: Vec::new(),
            round_out_p: Vec::new(),
            stats: TreeStats::default(),
        }
    }

    /// Tear the tree down, recovering the kernel for the next tree.
    pub fn into_kernel(self) -> BlockKernelKv {
        self.kernel
    }

    pub fn stats(&self) -> TreeStats {
        self.stats
    }

    /// Block size R.
    pub fn r(&self) -> usize {
        self.r
    }

    /// One scheduling round — [`super::tree::MergeTree::pump_round`]
    /// with the payload columns carried beside every key buffer.
    fn pump_round(&mut self) -> Result<bool> {
        let r = self.r;
        let cap = 2 * r;
        let MergeTreeKv { kernel, leaves, nodes, staged, round_out_k, round_out_p, stats, .. } =
            self;
        staged.clear();
        let mut flushed = false;
        for n in 0..nodes.len() {
            if nodes[n].done {
                continue;
            }
            nodes[n].compact();
            if cap - nodes[n].avail() < r {
                continue; // output backpressure: wait for the parent
            }
            let (li, ri) = (nodes[n].left, nodes[n].right);
            let pl = peek_input(nodes, leaves, li)?;
            let pr = peek_input(nodes, leaves, ri)?;
            // The refill rule: take the next block from the input whose
            // head is smaller (ties to the left; exhausted = +∞).
            let (chosen, other_head) = match (pl, pr) {
                (Peek::Pending, _) | (_, Peek::Pending) => continue,
                (Peek::Exhausted, Peek::Exhausted) => {
                    let node = &mut nodes[n];
                    let NodeKv { merger, out_k, out_p, done, .. } = node;
                    merger.flush(out_k, out_p);
                    *done = true;
                    stats.flushes += 1;
                    flushed = true;
                    continue;
                }
                (Peek::Key(x), Peek::Key(y)) => {
                    if x <= y {
                        (li, Some(y))
                    } else {
                        (ri, Some(x))
                    }
                }
                (Peek::Key(_), Peek::Exhausted) => (li, None),
                (Peek::Exhausted, Peek::Key(_)) => (ri, None),
            };
            let taken = match chosen {
                Input::Leaf(l) => {
                    let node = &mut nodes[n];
                    let (bk, bp) = node.merger.stage_bufs();
                    leaves[l].take(r, bk, bp)?
                }
                Input::Node(c) => {
                    // Children index below parents (post-order build).
                    let (head, tail) = nodes.split_at_mut(n);
                    let (bk, bp) = tail[0].merger.stage_bufs();
                    head[c].take(r, bk, bp)
                }
            };
            debug_assert!(taken >= 1, "chosen input had a peeked key");
            let k = nodes[n].merger.emit_count(other_head);
            let width = nodes[n].merger.width();
            staged.push(Staged { node: n, k, width });
        }
        if staged.is_empty() {
            return Ok(flushed);
        }
        // One ragged kernel batch over every staged node step.
        if round_out_k.len() < staged.len() {
            round_out_k.resize_with(staged.len(), Vec::new);
            round_out_p.resize_with(staged.len(), Vec::new);
        }
        for (s, st) in staged.iter().enumerate() {
            round_out_k[s].clear();
            round_out_k[s].resize(st.width, 0);
            round_out_p[s].clear();
            round_out_p[s].resize(st.width, 0);
        }
        let rows: Vec<&[Vec<u32>]> =
            staged.iter().map(|st| nodes[st.node].merger.lists()).collect();
        let pay_rows: Vec<[&[u64]; 2]> =
            staged.iter().map(|st| nodes[st.node].merger.pay_slices()).collect();
        let mut out_keys: Vec<&mut [u32]> =
            round_out_k[..staged.len()].iter_mut().map(|v| v.as_mut_slice()).collect();
        let mut out_pays: Vec<&mut [u64]> =
            round_out_p[..staged.len()].iter_mut().map(|v| v.as_mut_slice()).collect();
        kernel.merge_rows(&rows, &pay_rows, &mut out_keys, &mut out_pays);
        stats.kernel_batches += 1;
        stats.kernel_rows += staged.len() as u64;
        for (s, st) in staged.iter().enumerate() {
            let NodeKv { merger, out_k, out_p, .. } = &mut nodes[st.node];
            merger.apply(&round_out_k[s], &round_out_p[s], st.k, out_k, out_p);
        }
        Ok(true)
    }
}

impl SortedKvStream for MergeTreeKv<'_> {
    fn next_chunk(
        &mut self,
        max: usize,
        keys: &mut Vec<u32>,
        pays: &mut Vec<u64>,
    ) -> Result<usize> {
        let Some(root) = self.root else { return Ok(0) };
        match root {
            // k = 1: pass the single stream through its leaf buffer.
            Input::Leaf(l) => self.leaves[l].take(max, keys, pays),
            Input::Node(ri) => loop {
                let n = self.nodes[ri].take(max, keys, pays);
                if n > 0 {
                    return Ok(n);
                }
                if self.nodes[ri].done {
                    return Ok(0);
                }
                if !self.pump_round()? {
                    // Unreachable by construction — fail loudly rather
                    // than spin (same argument as the key-only tree).
                    bail!("streaming KV merge tree stalled");
                }
            },
        }
    }
}

/// Merge k sorted key-value streams into owned columns.
pub fn merge_k_kv<'a>(
    streams: Vec<Box<dyn SortedKvStream + 'a>>,
    r: usize,
) -> Result<(Vec<u32>, Vec<u64>)> {
    let mut tree = MergeTreeKv::new(streams, r)?;
    let mut keys = Vec::new();
    let mut pays = Vec::new();
    while tree.next_chunk(DRAIN, &mut keys, &mut pays)? > 0 {}
    Ok((keys, pays))
}

/// Merge in-memory sorted key-value runs.
pub fn merge_runs_kv(runs: &[(Vec<u32>, Vec<u64>)], r: usize) -> Result<(Vec<u32>, Vec<u64>)> {
    let streams: Vec<Box<dyn SortedKvStream + '_>> =
        runs.iter().map(|(k, p)| boxed_kv(SliceKvStream::new(k, p))).collect();
    merge_k_kv(streams, r)
}

/// Monotonic KV spill-file id (pid keeps parallel processes apart).
fn next_spill_path(dir: &Path) -> PathBuf {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let id = NEXT.fetch_add(1, Ordering::Relaxed);
    dir.join(format!("loms-kvspill-{}-{id}.kv12", std::process::id()))
}

/// Where encoded KV spill bytes go — see the key-only twin in
/// [`super::extsort`]: buffered synchronous writes when the caller is
/// already a dedicated sink thread, write-behind when the caller is the
/// merge thread itself.
enum SegSinkKv {
    Buf(BufWriter<File>),
    Behind(WriteBehind),
}

/// Append-only writer for segmented KV spill files of sorted runs —
/// the key-only `SpillWriter` with 12-byte records. Rotates to a fresh
/// file every `cap` runs and registers every file (and checksum
/// sidecar) with the [`SpillGuard`]. Failures on this path are typed
/// [`io::ExtSortError::Spill`]s, never panics.
struct SpillWriterKv {
    dir: PathBuf,
    guard: SpillGuard,
    wait: IoWait,
    behind: bool,
    /// Checksum segments into `.crc` sidecars as they are written.
    verify: bool,
    cap: usize,
    sink: Option<(SegSinkKv, PathBuf)>,
    /// Rolling per-block CRC of the open segment (when verifying).
    sum: Option<SpillChecksum>,
    runs: Vec<(u64, u64)>,
    segs: Vec<SpillSeg>,
    /// Records written into the open segment.
    pos: u64,
    cur: Option<u64>,
    bytes: Vec<u8>,
}

impl SpillWriterKv {
    fn new(
        dir: PathBuf,
        cap: usize,
        behind: bool,
        verify: bool,
        guard: SpillGuard,
        wait: IoWait,
    ) -> SpillWriterKv {
        SpillWriterKv {
            dir,
            guard,
            wait,
            behind,
            verify,
            cap: cap.max(1),
            sink: None,
            sum: None,
            runs: Vec::new(),
            segs: Vec::new(),
            pos: 0,
            cur: None,
            bytes: Vec::new(),
        }
    }

    fn open_seg(&mut self) -> Result<()> {
        let path = next_spill_path(&self.dir);
        let f = File::create(&path).map_err(|e| spill_io(e, "creating KV spill file", &path))?;
        self.guard.register(&path);
        let sink = if self.behind {
            SegSinkKv::Behind(
                WriteBehind::spawn(f, self.wait.clone())
                    .map_err(|e| spill_io(e, "starting write-behind for", &path))?,
            )
        } else {
            SegSinkKv::Buf(BufWriter::new(f))
        };
        self.sum = self.verify.then(|| SpillChecksum::new(REC_BYTES as usize));
        self.sink = Some((sink, path));
        Ok(())
    }

    fn begin_run(&mut self) -> Result<()> {
        debug_assert!(self.cur.is_none());
        if self.sink.is_none() {
            self.open_seg()?;
        }
        self.cur = Some(self.pos);
        Ok(())
    }

    fn write_records(&mut self, keys: &[u32], pays: &[u64]) -> Result<()> {
        let SpillWriterKv { sink, bytes, wait, pos, sum, .. } = self;
        let Some((sink, path)) = sink.as_mut() else {
            bail!("KV spill write outside an open segment");
        };
        if fault::fires(Site::SpillWriteEnospc) {
            return Err(spill_io(fault::enospc(), "writing KV spill run to", path));
        }
        match sink {
            SegSinkKv::Buf(w) => {
                encode_records_into(keys, pays, bytes);
                if let Some(sum) = sum.as_mut() {
                    sum.update(bytes);
                }
                wait.timed_phase(IoPhase::SpillWrite, || w.write_all(bytes))
                    .map_err(|e| spill_io(e, "writing KV spill run to", path))?;
            }
            SegSinkKv::Behind(wb) => {
                let mut b = wb.buffer();
                encode_records_into(keys, pays, &mut b);
                if let Some(sum) = sum.as_mut() {
                    sum.update(&b);
                }
                wb.submit(b).map_err(|e| spill_io(e, "writing KV spill run to", path))?;
            }
        }
        *pos += keys.len() as u64;
        Ok(())
    }

    fn end_run(&mut self) -> Result<()> {
        let Some(start) = self.cur.take() else {
            bail!("KV spill run closed without begin_run");
        };
        self.runs.push((start, self.pos - start));
        if self.runs.len() >= self.cap {
            self.close_seg()?;
        }
        Ok(())
    }

    fn push_run(&mut self, keys: &[u32], pays: &[u64]) -> Result<()> {
        self.begin_run()?;
        self.write_records(keys, pays)?;
        self.end_run()
    }

    fn close_seg(&mut self) -> Result<()> {
        let Some((sink, path)) = self.sink.take() else { return Ok(()) };
        match sink {
            SegSinkKv::Buf(mut w) => self
                .wait
                .timed(|| w.flush())
                .map_err(|e| spill_io(e, "flushing KV spill segment", &path))?,
            SegSinkKv::Behind(wb) => {
                wb.finish().map_err(|e| spill_io(e, "flushing KV spill segment", &path))?
            }
        }
        if let Some(sum) = self.sum.take() {
            let side = sidecar_path(&path);
            self.guard.register(&side);
            let entries = sum.finish();
            self.wait
                .timed(|| std::fs::write(&side, &entries))
                .map_err(|e| spill_io(e, "writing KV spill sidecar", &side))?;
        }
        self.segs.push(SpillSeg { path, runs: std::mem::take(&mut self.runs) });
        self.pos = 0;
        Ok(())
    }

    fn finish(mut self) -> Result<Vec<SpillSeg>> {
        self.close_seg()?;
        Ok(std::mem::take(&mut self.segs))
    }
}

/// Where the current generation of KV runs lives.
enum RunStoreKv {
    Mem(Vec<(Vec<u32>, Vec<u64>)>),
    Files(Vec<SpillSeg>),
}

/// Open one KV spill run as a stream. With `verify` set the run reads
/// through the checksummed [`SpillRunKvStream`] (block-verified, with
/// bounded re-read recovery); otherwise through the raw readers —
/// prefetched when a buffer is configured and the run outgrows it,
/// synchronous otherwise.
fn open_kv_run(
    path: &Path,
    start: u64,
    len: u64,
    prefetch: usize,
    verify: bool,
    wait: &IoWait,
) -> Result<Box<dyn SortedKvStream + 'static>> {
    if verify {
        let pf = if len <= prefetch as u64 { 0 } else { prefetch };
        Ok(boxed_kv(SpillRunKvStream::open(path, start, len, pf, wait.clone())?))
    } else if prefetch == 0 || len <= prefetch as u64 {
        Ok(boxed_kv(FileRunKvStream::open(path, start, len)?))
    } else {
        Ok(boxed_kv(PrefetchRunKvStream::open(path, start, len, prefetch, wait.clone())?))
    }
}

impl RunStoreKv {
    fn count(&self) -> usize {
        match self {
            RunStoreKv::Mem(runs) => runs.len(),
            RunStoreKv::Files(segs) => segs.iter().map(|s| s.runs.len()).sum(),
        }
    }

    /// Flatten the segmented layout into `(path, start, len)` per run.
    fn flat_runs(&self) -> Vec<(&Path, u64, u64)> {
        match self {
            RunStoreKv::Mem(_) => Vec::new(),
            RunStoreKv::Files(segs) => segs
                .iter()
                .flat_map(|s| s.runs.iter().map(|&(start, len)| (s.path.as_path(), start, len)))
                .collect(),
        }
    }

    fn open(
        &self,
        lo: usize,
        hi: usize,
        prefetch: usize,
        verify: bool,
        wait: &IoWait,
    ) -> Result<Vec<Box<dyn SortedKvStream + '_>>> {
        match self {
            RunStoreKv::Mem(runs) => Ok(runs[lo..hi]
                .iter()
                .map(|(k, p)| boxed_kv(SliceKvStream::new(k, p)))
                .collect()),
            RunStoreKv::Files(_) => self.flat_runs()[lo..hi]
                .iter()
                .map(|&(path, start, len)| open_kv_run(path, start, len, prefetch, verify, wait))
                .collect(),
        }
    }

    fn cleanup(self, guard: &SpillGuard) {
        if let RunStoreKv::Files(segs) = self {
            for seg in segs {
                io::remove_seg(guard, &seg.path);
            }
        }
    }
}

/// Sort one run's pairs **stably** by key (duplicate keys keep their
/// arrival order, matching the rank-then-permute merge semantics).
fn sort_run(keys: &[u32], pays: &[u64]) -> (Vec<u32>, Vec<u64>) {
    let mut pairs: Vec<(u32, u64)> =
        keys.iter().copied().zip(pays.iter().copied()).collect();
    pairs.sort_by_key(|&(k, _)| k);
    (pairs.iter().map(|&(k, _)| k).collect(), pairs.iter().map(|&(_, p)| p).collect())
}

fn drain_to_vecs(
    mut tree: MergeTreeKv<'_>,
    keys: &mut Vec<u32>,
    pays: &mut Vec<u64>,
    tstats: &mut TreeStats,
) -> Result<BlockKernelKv> {
    while tree.next_chunk(DRAIN, keys, pays)? > 0 {}
    tstats.absorb(tree.stats());
    Ok(tree.into_kernel())
}

/// One intermediate KV pass: merge groups of `max_fanin` runs into the
/// next generation (memory→memory or spill→spill), unlinking each
/// consumed spill segment as soon as its last run drains — the rolling
/// pass that keeps the disk footprint near one copy of the data.
fn merge_pass_kv(
    store: RunStoreKv,
    cfg: &ExtSortConfig,
    stats: &mut ExtSortStats,
    mut kernel: BlockKernelKv,
    guard: &SpillGuard,
    wait: &IoWait,
) -> Result<(RunStoreKv, BlockKernelKv)> {
    let count = store.count();
    match store {
        RunStoreKv::Mem(_) => {
            let mut runs = Vec::with_capacity(count.div_ceil(cfg.max_fanin));
            let mut lo = 0;
            while lo < count {
                let hi = (lo + cfg.max_fanin).min(count);
                let (mut rk, mut rp) = (Vec::new(), Vec::new());
                let tree = MergeTreeKv::with_kernel(
                    store.open(lo, hi, cfg.prefetch_buf, cfg.verify_spill, wait)?,
                    kernel,
                );
                kernel = drain_to_vecs(tree, &mut rk, &mut rp, &mut stats.tree)?;
                runs.push((rk, rp));
                lo = hi;
            }
            Ok((RunStoreKv::Mem(runs), kernel))
        }
        RunStoreKv::Files(ref segs) => {
            let dir = segs
                .first()
                .and_then(|s| s.path.parent())
                .map(Path::to_path_buf)
                .unwrap_or_else(|| PathBuf::from("."));
            let seg_ends: Vec<usize> = segs
                .iter()
                .scan(0usize, |acc, s| {
                    *acc += s.runs.len();
                    Some(*acc)
                })
                .collect();
            let mut w = SpillWriterKv::new(
                dir,
                cfg.max_fanin,
                true,
                cfg.verify_spill,
                guard.clone(),
                wait.clone(),
            );
            let (mut ck, mut cp) = (Vec::with_capacity(DRAIN), Vec::with_capacity(DRAIN));
            let mut lo = 0;
            let mut consumed_segs = 0;
            while lo < count {
                let hi = (lo + cfg.max_fanin).min(count);
                let mut tree = MergeTreeKv::with_kernel(
                    store.open(lo, hi, cfg.prefetch_buf, cfg.verify_spill, wait)?,
                    kernel,
                );
                w.begin_run()?;
                loop {
                    ck.clear();
                    cp.clear();
                    if tree.next_chunk(DRAIN, &mut ck, &mut cp)? == 0 {
                        break;
                    }
                    w.write_records(&ck, &cp)?;
                }
                w.end_run()?;
                stats.tree.absorb(tree.stats());
                kernel = tree.into_kernel();
                if let RunStoreKv::Files(segs) = &store {
                    while consumed_segs < segs.len() && seg_ends[consumed_segs] <= hi {
                        io::remove_seg(guard, &segs[consumed_segs].path);
                        consumed_segs += 1;
                    }
                }
                lo = hi;
            }
            let segs_out = w.finish()?;
            stats.spilled_runs += segs_out.iter().map(|s| s.runs.len()).sum::<usize>();
            stats.spill_bytes += segs_out
                .iter()
                .flat_map(|s| s.runs.iter())
                .map(|&(_, len)| len * REC_BYTES)
                .sum::<u64>();
            Ok((RunStoreKv::Files(segs_out), kernel))
        }
    }
}

/// Phase-1 stable run formation over in-memory columns, sharded across
/// `threads` scoped workers on contiguous chunk groups.
fn form_runs_mem_kv(
    keys: &[u32],
    pays: &[u64],
    run_len: usize,
    threads: usize,
    wait: &IoWait,
) -> Result<Vec<(Vec<u32>, Vec<u64>)>> {
    let sort_one = |ck: &[u32], cp: &[u64]| wait.timed_phase(IoPhase::ChunkSort, || sort_run(ck, cp));
    let chunks: Vec<(&[u32], &[u64])> =
        keys.chunks(run_len).zip(pays.chunks(run_len)).collect();
    if threads <= 1 || chunks.len() <= 1 {
        return Ok(chunks.iter().map(|&(ck, cp)| sort_one(ck, cp)).collect());
    }
    let per = chunks.len().div_ceil(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .chunks(per)
            .map(|group| {
                s.spawn(move || group.iter().map(|&(ck, cp)| sort_one(ck, cp)).collect::<Vec<_>>())
            })
            .collect();
        let mut runs = Vec::with_capacity(chunks.len());
        for h in handles {
            runs.extend(h.join().map_err(|_| anyhow!("KV run-sort worker panicked"))?);
        }
        Ok(runs)
    })
}

/// External key-value sort: form stable runs (sharded across
/// `sort_threads`), optionally spill them as 12-byte records, merge
/// pass by pass through [`MergeTreeKv`], stream the final k-way merge
/// into owned columns (range-partitioned across cores when the runs
/// are in memory). Each payload is moved by I/O and the per-row
/// permutation gather only — never by a compare-exchange.
pub fn extsort_kv(
    keys: &[u32],
    pays: &[u64],
    cfg: &ExtSortConfig,
) -> Result<(Vec<u32>, Vec<u64>, ExtSortStats)> {
    anyhow::ensure!(keys.len() == pays.len(), "key/payload columns differ in length");
    anyhow::ensure!(cfg.run_len >= 1, "run_len must be >= 1");
    anyhow::ensure!(cfg.max_fanin >= 2, "max_fanin must be >= 2");
    let mut kernel = BlockKernelKv::new(cfg.r)?;
    let mut stats = ExtSortStats { keys: keys.len(), ..Default::default() };
    if keys.is_empty() {
        stats.partitions = 1;
        return Ok((Vec::new(), Vec::new(), stats));
    }
    let guard = SpillGuard::new();
    let wait = IoWait::new();
    let threads = part::resolve_threads(cfg.sort_threads);
    let t0 = Instant::now();
    let mut store = match &cfg.spill_dir {
        None => RunStoreKv::Mem(form_runs_mem_kv(keys, pays, cfg.run_len, threads, &wait)?),
        Some(dir) => {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating spill dir {}", dir.display()))?;
            let w = SpillWriterKv::new(
                dir.clone(),
                cfg.max_fanin,
                false,
                cfg.verify_spill,
                guard.clone(),
                wait.clone(),
            );
            let segs = if threads > 1 {
                let mut chunks = keys.chunks(cfg.run_len).zip(pays.chunks(cfg.run_len));
                let wait = &wait;
                pipeline(
                    threads,
                    || Ok(chunks.next()),
                    |(ck, cp): (&[u32], &[u64])| {
                        wait.timed_phase(IoPhase::ChunkSort, || sort_run(ck, cp))
                    },
                    w,
                    |w, (rk, rp)| w.push_run(&rk, &rp),
                )?
                .finish()?
            } else {
                let mut w = w;
                for (ck, cp) in keys.chunks(cfg.run_len).zip(pays.chunks(cfg.run_len)) {
                    let (rk, rp) = wait.timed_phase(IoPhase::ChunkSort, || sort_run(ck, cp));
                    w.push_run(&rk, &rp)?;
                }
                w.finish()?
            };
            stats.spilled_runs += segs.iter().map(|s| s.runs.len()).sum::<usize>();
            stats.spill_bytes += REC_BYTES * keys.len() as u64;
            RunStoreKv::Files(segs)
        }
    };
    stats.runs = store.count();
    stats.run_form_secs = t0.elapsed().as_secs_f64();
    let tm = Instant::now();
    while store.count() > cfg.max_fanin {
        (store, kernel) = merge_pass_kv(store, cfg, &mut stats, kernel, &guard, &wait)?;
        stats.merge_passes += 1;
    }
    let (out_k, out_p) = match &store {
        RunStoreKv::Mem(runs)
            if runs.len() > 1 && part::resolve_partitions(cfg.partitions, keys.len()) > 1 =>
        {
            let (ok, op, nparts, tstats) =
                part::merge_runs_kv_parallel_stats(runs, cfg.r, cfg.partitions)?;
            stats.partitions = nparts;
            stats.tree.absorb(tstats);
            (ok, op)
        }
        _ => {
            let (mut ok, mut op) =
                (Vec::with_capacity(keys.len()), Vec::with_capacity(keys.len()));
            let streams =
                store.open(0, store.count(), cfg.prefetch_buf, cfg.verify_spill, &wait)?;
            let _ = drain_to_vecs(
                MergeTreeKv::with_kernel(streams, kernel),
                &mut ok,
                &mut op,
                &mut stats.tree,
            )?;
            stats.partitions = 1;
            (ok, op)
        }
    };
    store.cleanup(&guard);
    stats.merge_secs = tm.elapsed().as_secs_f64();
    stats.absorb_wait(&wait);
    Ok((out_k, out_p, stats))
}

/// Phase 3 of a KV file sort — the key-value twin of the key-only
/// partitioned final pass: cut every run at the sampled pivots (stride
/// 12), pre-size the output, and merge each key range on its own thread
/// into its own disjoint region. The cut rule sends all duplicates of a
/// pivot to one partition, so arrival order among equal keys (and hence
/// the output bytes) is identical to the single-tree merge.
fn final_merge_kv_file(
    store: &RunStoreKv,
    output: &Path,
    total: u64,
    cfg: &ExtSortConfig,
    stats: &mut ExtSortStats,
    wait: &IoWait,
    kernel: BlockKernelKv,
) -> Result<()> {
    let runs = store.flat_runs();
    let parts = part::resolve_partitions(cfg.partitions, total as usize);
    if parts <= 1 || runs.len() <= 1 || total == 0 {
        let f = File::create(output)
            .with_context(|| format!("creating {}", output.display()))?;
        let mut wb = WriteBehind::spawn(f, wait.clone()).context("starting output writer")?;
        let mut tree = MergeTreeKv::with_kernel(
            store.open(0, store.count(), cfg.prefetch_buf, cfg.verify_spill, wait)?,
            kernel,
        );
        let (mut ck, mut cp) = (Vec::with_capacity(DRAIN), Vec::with_capacity(DRAIN));
        loop {
            ck.clear();
            cp.clear();
            if tree.next_chunk(DRAIN, &mut ck, &mut cp)? == 0 {
                break;
            }
            let mut b = wb.buffer();
            encode_records_into(&ck, &cp, &mut b);
            wb.submit(b).context("writing sorted output")?;
        }
        stats.tree.absorb(tree.stats());
        wb.finish().context("writing sorted output")?;
        stats.partitions = 1;
        return Ok(());
    }
    let mut samples = Vec::new();
    for &(path, start, len) in &runs {
        FileCutter::open(path, start, len, REC_BYTES)?.sample_into(&mut samples)?;
    }
    let pivots = part::pivots_from_samples(samples, parts);
    let cuts: Vec<Vec<u64>> = runs
        .iter()
        .map(|&(path, start, len)| FileCutter::open(path, start, len, REC_BYTES)?.cuts(&pivots))
        .collect::<Result<_>>()?;
    // Corrupt (unsorted) spill data can make the binary-search cuts
    // non-monotone, which would underflow the per-partition sizes below.
    for (c, &(path, _, len)) in cuts.iter().zip(&runs) {
        anyhow::ensure!(
            c.windows(2).all(|w| w[0] <= w[1]) && c.last().is_none_or(|&e| e <= len),
            "non-monotone partition cuts for {} (corrupt spill data?)",
            path.display()
        );
    }
    let nparts = pivots.len() + 1;
    let sizes: Vec<u64> =
        (0..nparts).map(|p| cuts.iter().map(|c| c[p + 1] - c[p]).sum()).collect();
    let mut offs = Vec::with_capacity(nparts);
    let mut acc = 0u64;
    for &sz in &sizes {
        offs.push(acc);
        acc += sz;
    }
    anyhow::ensure!(acc == total, "KV partition cuts lost records ({acc} of {total})");
    File::create(output)
        .and_then(|f| f.set_len(total * REC_BYTES))
        .with_context(|| format!("creating {}", output.display()))?;
    let (runs, cuts, sizes, offs) = (&runs, &cuts, &sizes, &offs);
    let part_stats = std::thread::scope(|s| {
        let handles: Vec<_> = (0..nparts)
            .filter(|&p| sizes[p] > 0)
            .map(|p| {
                s.spawn(move || -> Result<TreeStats> {
                    let mut f = File::options()
                        .write(true)
                        .open(output)
                        .with_context(|| format!("opening {} region", output.display()))?;
                    f.seek(SeekFrom::Start(offs[p] * REC_BYTES))?;
                    let mut wb =
                        WriteBehind::spawn(f, wait.clone()).context("starting output writer")?;
                    let streams: Vec<Box<dyn SortedKvStream + '_>> = runs
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| cuts[*i][p + 1] > cuts[*i][p])
                        .map(|(i, &(path, start, _))| {
                            open_kv_run(
                                path,
                                start + cuts[i][p],
                                cuts[i][p + 1] - cuts[i][p],
                                cfg.prefetch_buf,
                                cfg.verify_spill,
                                wait,
                            )
                        })
                        .collect::<Result<_>>()?;
                    let mut tree = MergeTreeKv::new(streams, cfg.r)?;
                    let (mut ck, mut cp) =
                        (Vec::with_capacity(DRAIN), Vec::with_capacity(DRAIN));
                    let mut written = 0u64;
                    loop {
                        ck.clear();
                        cp.clear();
                        let n = tree.next_chunk(DRAIN, &mut ck, &mut cp)?;
                        if n == 0 {
                            break;
                        }
                        let mut b = wb.buffer();
                        encode_records_into(&ck, &cp, &mut b);
                        wb.submit(b).context("writing sorted output")?;
                        written += n as u64;
                    }
                    anyhow::ensure!(
                        written == sizes[p],
                        "KV partition {p} wrote {written} of {} records",
                        sizes[p]
                    );
                    wb.finish().context("writing sorted output")?;
                    Ok(tree.stats())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow!("KV partition merge panicked"))?)
            .collect::<Result<Vec<TreeStats>>>()
    })?;
    for st in part_stats {
        stats.tree.absorb(st);
    }
    stats.partitions = nparts;
    Ok(())
}

/// Sort a file of 12-byte little-endian `(u32 key, u64 payload)`
/// records into `output` in bounded memory — the key-value twin of
/// [`super::extsort::extsort_file`]: pipelined run formation across
/// `sort_threads`, prefetched spill reads, write-behind spill writes,
/// rolling segment deletion, and a range-partitioned final pass. Spill
/// files are unlinked even when the sort fails partway. Backs
/// `loms sort --payload`.
pub fn extsort_kv_file(input: &Path, output: &Path, cfg: &ExtSortConfig) -> Result<ExtSortStats> {
    anyhow::ensure!(cfg.run_len >= 1, "run_len must be >= 1");
    anyhow::ensure!(cfg.max_fanin >= 2, "max_fanin must be >= 2");
    let mut kernel = BlockKernelKv::new(cfg.r)?;
    let bytes = std::fs::metadata(input)
        .with_context(|| format!("stat {}", input.display()))?
        .len();
    anyhow::ensure!(
        bytes % REC_BYTES == 0,
        "{}: not a whole number of 12-byte key-value records",
        input.display()
    );
    let total = bytes / REC_BYTES;
    let mut stats = ExtSortStats { keys: total as usize, ..Default::default() };
    let dir = cfg
        .spill_dir
        .clone()
        .or_else(|| output.parent().map(Path::to_path_buf).filter(|p| !p.as_os_str().is_empty()))
        .unwrap_or_else(|| PathBuf::from("."));
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating spill dir {}", dir.display()))?;
    let guard = SpillGuard::new();
    let wait = IoWait::new();
    let threads = part::resolve_threads(cfg.sort_threads);
    let t0 = Instant::now();
    // Phase 1: read run_len-record windows in order, stable-sort across
    // the worker pool, spill in order from the sink thread.
    let mut store = {
        let mut rd = BufReader::with_capacity(
            1 << 20,
            File::open(input).with_context(|| format!("opening {}", input.display()))?,
        );
        let mut remaining = total;
        let produce = || -> Result<Option<(Vec<u32>, Vec<u64>)>> {
            if remaining == 0 {
                return Ok(None);
            }
            let n = (cfg.run_len as u64).min(remaining) as usize;
            let mut buf = vec![0u8; n * REC_BYTES as usize];
            wait.timed(|| rd.read_exact(&mut buf)).context("reading input records")?;
            let (mut ck, mut cp) = (Vec::with_capacity(n), Vec::with_capacity(n));
            decode_records_into(&buf, &mut ck, &mut cp);
            remaining -= n as u64;
            Ok(Some((ck, cp)))
        };
        let w = SpillWriterKv::new(
            dir.clone(),
            cfg.max_fanin,
            false,
            cfg.verify_spill,
            guard.clone(),
            wait.clone(),
        );
        let segs = if threads > 1 {
            let wait = &wait;
            pipeline(
                threads,
                produce,
                |(ck, cp): (Vec<u32>, Vec<u64>)| {
                    wait.timed_phase(IoPhase::ChunkSort, || sort_run(&ck, &cp))
                },
                w,
                |w, (rk, rp)| w.push_run(&rk, &rp),
            )?
            .finish()?
        } else {
            let mut w = w;
            let mut produce = produce;
            while let Some((ck, cp)) = produce()? {
                let (rk, rp) = wait.timed_phase(IoPhase::ChunkSort, || sort_run(&ck, &cp));
                w.push_run(&rk, &rp)?;
            }
            w.finish()?
        };
        stats.spilled_runs += segs.iter().map(|s| s.runs.len()).sum::<usize>();
        stats.spill_bytes += bytes;
        RunStoreKv::Files(segs)
    };
    stats.runs = store.count();
    stats.run_form_secs = t0.elapsed().as_secs_f64();
    let tm = Instant::now();
    while store.count() > cfg.max_fanin {
        (store, kernel) = merge_pass_kv(store, cfg, &mut stats, kernel, &guard, &wait)?;
        stats.merge_passes += 1;
    }
    // Phase 3: partition-parallel merge straight into the output file.
    final_merge_kv_file(&store, output, total, cfg, &mut stats, &wait, kernel)?;
    store.cleanup(&guard);
    stats.merge_secs = tm.elapsed().as_secs_f64();
    stats.absorb_wait(&wait);
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Full-discrimination oracle: merged keys equal the sorted key
    /// concat AND the (key, payload) pair multiset is preserved — with
    /// globally unique payloads this proves every duplicate key carried
    /// exactly the payload it arrived with.
    fn check_kv(got_k: &[u32], got_p: &[u64], inputs: &[(Vec<u32>, Vec<u64>)]) {
        let mut want_k: Vec<u32> =
            inputs.iter().flat_map(|(k, _)| k.iter().copied()).collect();
        want_k.sort_unstable();
        assert_eq!(got_k, want_k.as_slice(), "merged keys");
        assert_eq!(got_k.len(), got_p.len(), "column widths");
        let mut got_pairs: Vec<(u32, u64)> =
            got_k.iter().copied().zip(got_p.iter().copied()).collect();
        let mut want_pairs: Vec<(u32, u64)> = inputs
            .iter()
            .flat_map(|(k, p)| k.iter().copied().zip(p.iter().copied()))
            .collect();
        got_pairs.sort_unstable();
        want_pairs.sort_unstable();
        assert_eq!(got_pairs, want_pairs, "(key, payload) pair multiset");
    }

    /// Random sorted keys with globally unique payload tags.
    fn tagged_run(rng: &mut Rng, len: usize, max: u32, tag: u64) -> (Vec<u32>, Vec<u64>) {
        let keys = rng.sorted_list(len, max);
        let pays = (0..keys.len() as u64).map(|i| (tag << 32) | i).collect();
        (keys, pays)
    }

    #[test]
    fn kernel_merges_pairs_with_payloads_intact() {
        let mut kern = BlockKernelKv::new(8).unwrap();
        assert_eq!(kern.r(), 8);
        assert!(kern.device_name().contains("loms"));
        let mut rng = Rng::new(0x1257);
        for case in 0..40 {
            // Duplicate-heavy small key domain every few cases.
            let max = if case % 3 == 0 { 6 } else { 1 << 20 };
            let (ak, ap) = tagged_run(&mut rng, rng.range(0, 9), max, 1);
            let (bk, bp) = tagged_run(&mut rng, rng.range(0, 9), max, 2);
            let lists = [ak.clone(), bk.clone()];
            let width = ak.len() + bk.len();
            let mut out_k = vec![0u32; width];
            let mut out_p = vec![0u64; width];
            kern.merge_rows(
                &[&lists],
                &[[&ap, &bp]],
                &mut [&mut out_k[..]],
                &mut [&mut out_p[..]],
            );
            check_kv(&out_k, &out_p, &[(ak.clone(), ap.clone()), (bk.clone(), bp.clone())]);
            // The node merge is stable: equal keys emit list 0 first,
            // each list in arrival order — exactly a stable sort of the
            // zipped concat.
            let mut pairs: Vec<(u32, u64)> = ak
                .iter()
                .copied()
                .zip(ap.iter().copied())
                .chain(bk.iter().copied().zip(bp.iter().copied()))
                .collect();
            pairs.sort_by_key(|&(k, _)| k);
            let want_p: Vec<u64> = pairs.iter().map(|&(_, p)| p).collect();
            assert_eq!(out_p, want_p, "case {case}: stable payload order");
        }
    }

    #[test]
    fn kernel_batches_independent_rows() {
        let mut kern = BlockKernelKv::new(4).unwrap();
        let mut rng = Rng::new(0xBA7D);
        let n_rows = crate::sortnet::lanes::LANES + 5;
        let pairs: Vec<[(Vec<u32>, Vec<u64>); 2]> = (0..n_rows)
            .map(|i| {
                [
                    tagged_run(&mut rng, rng.range(0, 5), 100, 2 * i as u64),
                    tagged_run(&mut rng, rng.range(1, 5), 100, 2 * i as u64 + 1),
                ]
            })
            .collect();
        let key_rows: Vec<[Vec<u32>; 2]> =
            pairs.iter().map(|p| [p[0].0.clone(), p[1].0.clone()]).collect();
        let rows: Vec<&[Vec<u32>]> = key_rows.iter().map(|p| &p[..]).collect();
        let pay_rows: Vec<[&[u64]; 2]> =
            pairs.iter().map(|p| [p[0].1.as_slice(), p[1].1.as_slice()]).collect();
        let widths: Vec<usize> = pairs.iter().map(|p| p[0].0.len() + p[1].0.len()).collect();
        let mut out_k: Vec<Vec<u32>> = widths.iter().map(|&w| vec![0u32; w]).collect();
        let mut out_p: Vec<Vec<u64>> = widths.iter().map(|&w| vec![0u64; w]).collect();
        let mut key_outs: Vec<&mut [u32]> = out_k.iter_mut().map(|v| v.as_mut_slice()).collect();
        let mut pay_outs: Vec<&mut [u64]> = out_p.iter_mut().map(|v| v.as_mut_slice()).collect();
        kern.merge_rows(&rows, &pay_rows, &mut key_outs, &mut pay_outs);
        for (i, p) in pairs.iter().enumerate() {
            check_kv(&out_k[i], &out_p[i], &[p[0].clone(), p[1].clone()]);
        }
    }

    #[test]
    fn max_value_keys_are_legal() {
        // Unlike the serving path, u32::MAX is a legal stream key: it
        // packs below the u64::MAX pad because origins stay small.
        let mut kern = BlockKernelKv::new(4).unwrap();
        let ak = vec![1, u32::MAX - 1, u32::MAX];
        let ap = vec![10, 11, 12];
        let bk = vec![u32::MAX - 1, u32::MAX];
        let bp = vec![20, 21];
        let lists = [ak.clone(), bk.clone()];
        let mut out_k = vec![0u32; 5];
        let mut out_p = vec![0u64; 5];
        kern.merge_rows(&[&lists], &[[&ap, &bp]], &mut [&mut out_k[..]], &mut [&mut out_p[..]]);
        assert_eq!(out_k, vec![1, u32::MAX - 1, u32::MAX - 1, u32::MAX, u32::MAX]);
        assert_eq!(out_p, vec![10, 11, 20, 12, 21]);
    }

    #[test]
    fn merge_runs_matches_oracle_across_k_and_r() {
        let mut rng = Rng::new(0x7EF);
        for &k in &[2usize, 3, 5, 8, 17] {
            for &r in &[2usize, 8, 32] {
                let runs: Vec<(Vec<u32>, Vec<u64>)> = (0..k)
                    .map(|i| tagged_run(&mut rng, rng.range(0, 300), 5000, i as u64))
                    .collect();
                let (gk, gp) = merge_runs_kv(&runs, r).unwrap();
                check_kv(&gk, &gp, &runs);
            }
        }
    }

    #[test]
    fn degenerate_k() {
        let (k0, p0) = merge_k_kv(vec![], 8).unwrap();
        assert!(k0.is_empty() && p0.is_empty());
        let one: Vec<Box<dyn SortedKvStream>> =
            vec![boxed_kv(VecKvStream::new(vec![3, 4, 5], vec![30, 40, 50]))];
        assert_eq!(merge_k_kv(one, 8).unwrap(), (vec![3, 4, 5], vec![30, 40, 50]));
        let runs = vec![(vec![], vec![]), (vec![], vec![])];
        let (k2, p2) = merge_runs_kv(&runs, 8).unwrap();
        assert!(k2.is_empty() && p2.is_empty());
    }

    #[test]
    fn trees_compose_as_streams() {
        let mut rng = Rng::new(0xC1);
        let inner_runs: Vec<(Vec<u32>, Vec<u64>)> =
            (0..3).map(|i| tagged_run(&mut rng, 100, 1000, i as u64)).collect();
        let outer_run = tagged_run(&mut rng, 150, 1000, 99);
        let inner_streams: Vec<Box<dyn SortedKvStream + '_>> = inner_runs
            .iter()
            .map(|(k, p)| boxed_kv(SliceKvStream::new(k, p)))
            .collect();
        let inner = MergeTreeKv::new(inner_streams, 8).unwrap();
        let outer: Vec<Box<dyn SortedKvStream + '_>> = vec![
            boxed_kv(inner),
            boxed_kv(SliceKvStream::new(&outer_run.0, &outer_run.1)),
        ];
        let (gk, gp) = merge_k_kv(outer, 8).unwrap();
        let mut all = inner_runs;
        all.push(outer_run);
        check_kv(&gk, &gp, &all);
    }

    #[test]
    fn stats_count_batched_rows() {
        let mut rng = Rng::new(0x91);
        let runs: Vec<(Vec<u32>, Vec<u64>)> =
            (0..17).map(|i| tagged_run(&mut rng, 500, 1 << 20, i as u64)).collect();
        let streams: Vec<Box<dyn SortedKvStream + '_>> = runs
            .iter()
            .map(|(k, p)| boxed_kv(SliceKvStream::new(k, p)))
            .collect();
        let mut tree = MergeTreeKv::new(streams, 8).unwrap();
        let (mut gk, mut gp) = (Vec::new(), Vec::new());
        while tree.next_chunk(DRAIN, &mut gk, &mut gp).unwrap() > 0 {}
        check_kv(&gk, &gp, &runs);
        let st = tree.stats();
        assert!(st.kernel_rows > st.kernel_batches, "rounds batch multiple nodes: {st:?}");
        assert_eq!(st.flushes, 16, "every internal node flushes once");
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("loms_kvsort_{tag}_{}", std::process::id()))
    }

    #[test]
    fn in_memory_kv_sort_matches_stable_std() {
        let mut rng = Rng::new(0xE6);
        let keys: Vec<u32> = (0..10_000).map(|_| rng.next_u32() % 997).collect();
        let pays: Vec<u64> = (0..keys.len() as u64).collect();
        let cfg = ExtSortConfig { run_len: 700, r: 8, ..Default::default() };
        let (gk, gp, stats) = extsort_kv(&keys, &pays, &cfg).unwrap();
        check_kv(&gk, &gp, &[(keys, pays)]);
        assert_eq!(stats.runs, 10_000usize.div_ceil(700));
        assert_eq!(stats.merge_passes, 0);
        assert_eq!(stats.spilled_runs, 0);
        assert_eq!(gp.len(), gk.len());
    }

    #[test]
    fn multi_pass_spill_kv_sort_round_trips() {
        let dir = tmp_dir("multipass");
        let mut rng = Rng::new(0x5112);
        let mut keys: Vec<u32> = (0..20_000).map(|_| rng.next_u32()).collect();
        keys.extend([u32::MAX, u32::MAX - 1, u32::MAX]); // full domain legal
        let pays: Vec<u64> = (0..keys.len() as u64).map(|i| i ^ 0xDEAD_BEEF).collect();
        let cfg = ExtSortConfig {
            run_len: 512,
            r: 8,
            max_fanin: 3,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        };
        let (gk, gp, stats) = extsort_kv(&keys, &pays, &cfg).unwrap();
        check_kv(&gk, &gp, &[(keys, pays)]);
        assert!(stats.merge_passes >= 2, "fanin 3 over {} runs: {stats:?}", stats.runs);
        assert!(stats.spilled_runs > stats.runs, "intermediate runs spilled too");
        assert!(stats.spill_bytes > 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn file_to_file_kv_round_trip() {
        let dir = tmp_dir("file");
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("input.kv12");
        let output = dir.join("sorted.kv12");
        let mut rng = Rng::new(0xF17F);
        let keys: Vec<u32> = (0..5_000).map(|_| rng.next_u32() % 4099).collect();
        let pays: Vec<u64> = (0..keys.len() as u64).collect();
        let mut bytes = Vec::new();
        encode_records_into(&keys, &pays, &mut bytes);
        std::fs::write(&input, &bytes).unwrap();
        let cfg = ExtSortConfig {
            run_len: 333,
            r: 8,
            max_fanin: 4,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        };
        let stats = extsort_kv_file(&input, &output, &cfg).unwrap();
        assert_eq!(stats.keys, keys.len());
        assert!(stats.merge_passes >= 1);
        assert!(stats.partitions >= 1);
        let out = std::fs::read(&output).unwrap();
        let (mut gk, mut gp) = (Vec::new(), Vec::new());
        for rec in out.chunks_exact(12) {
            gk.push(u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]));
            gp.push(u64::from_le_bytes([
                rec[4], rec[5], rec[6], rec[7], rec[8], rec[9], rec[10], rec[11],
            ]));
        }
        check_kv(&gk, &gp, &[(keys, pays)]);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn file_run_kv_stream_reads_its_window() {
        let dir = tmp_dir("window");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("runs.kv12");
        let keys: Vec<u32> = (0..50).map(|x| x * 3).collect();
        let pays: Vec<u64> = (0..50).map(|x| x * 7).collect();
        let mut bytes = Vec::new();
        encode_records_into(&keys, &pays, &mut bytes);
        std::fs::write(&path, &bytes).unwrap();
        let mut a = FileRunKvStream::open(&path, 0, 20).unwrap();
        let mut b = FileRunKvStream::open(&path, 20, 30).unwrap();
        let (mut ak, mut ap) = (Vec::new(), Vec::new());
        while a.next_chunk(7, &mut ak, &mut ap).unwrap() > 0 {}
        assert_eq!(ak, keys[..20]);
        assert_eq!(ap, pays[..20]);
        let (mut bk, mut bp) = (Vec::new(), Vec::new());
        while b.next_chunk(9, &mut bk, &mut bp).unwrap() > 0 {}
        assert_eq!(bk, keys[20..]);
        assert_eq!(bp, pays[20..]);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn degenerate_sorts() {
        let cfg = ExtSortConfig { r: 4, ..Default::default() };
        let (k, p, _) = extsort_kv(&[], &[], &cfg).unwrap();
        assert!(k.is_empty() && p.is_empty());
        let (k, p, _) = extsort_kv(&[9], &[90], &cfg).unwrap();
        assert_eq!((k, p), (vec![9], vec![90]));
        // Mismatched columns rejected up front.
        assert!(extsort_kv(&[1, 2], &[1], &cfg).is_err());
    }
}
