//! `loms` — the coordinator binary.
//!
//! Subcommands:
//!   report   [--figure <id|all>] [--csv-dir DIR]   regenerate paper figures
//!   netgen   --kind K [options] [--out FILE]       export a device as JSON
//!   goldens  [--dir tests/golden]                  write the cross-check set
//!   validate --kind K [options]                    exhaustive 0-1 validation
//!   serve    [--artifacts DIR] [--requests N] [--payload true]
//!            [--listen ADDR [--workers N] [--duration-secs S]
//!             [--metrics-interval S] [--trace-sample N]
//!             [--trace-file FILE]]
//!            with --listen: serve the framed TCP protocol on ADDR
//!            (e.g. 127.0.0.1:7474) instead of the in-process demo;
//!            --payload true drives the demo with key-value requests;
//!            --metrics-interval S emits the full stats document as
//!            one JSON line every S seconds; --trace-sample N retains
//!            spans for every Nth trace id; --trace-file appends the
//!            retained spans as JSONL
//!   stats    --addr ADDR                            fetch and pretty-print
//!            the live stats document from a running `serve --listen`
//!   bench-net --addr ADDR [--conns N] [--inflight M] [--requests R]
//!            [--payload true] [--proto v2] [--smoke true]
//!            load-generate against a running `serve --listen`
//!            (--payload true sends v1.1 key-value requests;
//!            --proto v2 multiplexes over protocol-v2 request ids;
//!            --smoke true shrinks the run for CI gate checks)
//!   sort     [--engine stream|ladder] [--n N] [--input F [--output F]]
//!            [--r R] [--run-len L] [--fanin F] [--spill DIR]
//!            [--sort-threads T] [--partitions P] [--prefetch-buf K]
//!            [--verify-spill false] [--ladder-runs true] [--chunk C]
//!            [--artifacts DIR] [--payload true] [--stats true]
//!            external sort: bounded-memory streaming engine (default)
//!            or the service merge-ladder path; --payload true sorts
//!            (u32 key, u64 payload) pairs through rank-then-permute
//!            (--input/--output files hold 12-byte LE records);
//!            --sort-threads/--partitions default 0 = one per core,
//!            --prefetch-buf is keys per spill read-ahead buffer
//!            (0 = synchronous reads); --verify-spill false disables
//!            per-block CRC-32 spill checksums (on by default);
//!            --stats true prints phase timings and kernel counters
//!   selftest                                       quick end-to-end check
//!
//! (Arg parsing is hand-rolled: the offline build vendors no clap.)

use anyhow::{anyhow, bail, Context, Result};
use loms::bench::figures;
use loms::coordinator::{
    planner, Backend, MergeService, PjrtBackend, ServiceConfig, SoftwareBackend,
};
use loms::net::{self, NetClient, NetServer, NetServerConfig};
use loms::obs::{self, HistStats};
use loms::sortnet::validate::{validate_median_01, validate_merge_01};
use loms::sortnet::{batcher, json, loms as lomsnet, mwms, s2ms, MergeDevice};
use loms::stream::{self, ExtSortConfig, RunFormer};
use loms::util::Rng;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// Parse `--key value` pairs after the subcommand.
fn opts(args: &[String]) -> Result<HashMap<String, String>> {
    let mut m = HashMap::new();
    let mut it = args.iter();
    while let Some(k) = it.next() {
        let k = k
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --option, got {k:?}"))?;
        let v = it.next().cloned().unwrap_or_else(|| "true".into());
        m.insert(k.to_string(), v);
    }
    Ok(m)
}

fn get_usize(o: &HashMap<String, String>, k: &str, default: usize) -> Result<usize> {
    match o.get(k) {
        Some(v) => v.parse().with_context(|| format!("--{k} {v:?}")),
        None => Ok(default),
    }
}

/// Build a device from `--kind` + options (shared by netgen/validate).
fn device_from_opts(o: &HashMap<String, String>) -> Result<MergeDevice> {
    let kind = o.get("kind").map(String::as_str).unwrap_or("loms2");
    Ok(match kind {
        "loms2" => {
            let m = get_usize(o, "m", 8)?;
            let n = get_usize(o, "n", 8)?;
            let cols = get_usize(o, "cols", 2)?;
            lomsnet::loms_2way(m, n, cols)
        }
        "lomsk" => {
            let sizes: Vec<usize> = o
                .get("sizes")
                .map(String::as_str)
                .unwrap_or("7,7,7")
                .split(',')
                .map(|s| s.trim().parse().context("--sizes"))
                .collect::<Result<_>>()?;
            lomsnet::loms_kway(&sizes)
        }
        "loms3med" => lomsnet::loms_3way_median(get_usize(o, "r", 7)?),
        "s2ms" => s2ms::s2ms(get_usize(o, "m", 8)?, get_usize(o, "n", 8)?),
        "oem" => batcher::odd_even_merge(get_usize(o, "m", 8)?),
        "bims" => batcher::bitonic_merge(get_usize(o, "m", 8)?),
        "mwms" => mwms::mwms_3way(get_usize(o, "r", 7)?),
        "mwmsmed" => mwms::mwms_3way_median(get_usize(o, "r", 7)?),
        other => bail!("unknown --kind {other:?} (loms2|lomsk|loms3med|s2ms|oem|bims|mwms|mwmsmed)"),
    })
}

/// The golden device set shared with `python/tests/test_golden.py`.
fn golden_set() -> Vec<(&'static str, MergeDevice)> {
    vec![
        ("loms2_up8_dn8_2col", lomsnet::loms_2way(8, 8, 2)),
        ("loms2_up7_dn5_2col", lomsnet::loms_2way(7, 5, 2)),
        ("loms2_up32_dn32_8col", lomsnet::loms_2way(32, 32, 8)),
        ("loms3_7r", lomsnet::loms_kway(&[7, 7, 7])),
        ("oem_up8_dn8", batcher::odd_even_merge(8)),
        ("bims_up8_dn8", batcher::bitonic_merge(8)),
        ("s2ms_up7_dn5", s2ms::s2ms(7, 5)),
    ]
}

fn artifacts_dir(o: &HashMap<String, String>) -> String {
    o.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into())
}

/// Block size R for the streaming engine: the smallest square 2-way
/// shape in the artifact set (compiled artifacts when built, the
/// default software set otherwise), so the stream kernel mirrors a
/// shape the service actually serves.
fn default_block_r(o: &HashMap<String, String>) -> usize {
    let dir = artifacts_dir(o);
    let metas = if Path::new(&dir).join("manifest.json").exists() {
        match loms::runtime::Manifest::load(&dir) {
            Ok(m) => m.artifacts,
            Err(e) => {
                eprintln!("note: ignoring unreadable artifact manifest for --r default: {e:#}");
                Vec::new()
            }
        }
    } else {
        SoftwareBackend::default_set().artifacts()
    };
    metas.iter().filter_map(|m| m.square_2way()).min().unwrap_or(stream::DEFAULT_R)
}

/// Two ensure-and-report lines shared by every `sort` engine.
fn report_sorted(sorted: &[u32], n: usize, label: &str, dt: Duration) -> Result<()> {
    anyhow::ensure!(sorted.windows(2).all(|w| w[0] <= w[1]), "output not sorted!");
    anyhow::ensure!(sorted.len() == n, "lost keys");
    println!(
        "{label} sorted {n} keys in {dt:?} ({:.2} Mkeys/s)",
        n as f64 / dt.as_secs_f64() / 1e6
    );
    Ok(())
}

/// One `--stats true` line per I/O phase histogram.
fn report_phase_hist(name: &str, h: &HistStats) {
    println!(
        "  {name}: count={} mean={:.1}µs p50={}µs p90={}µs p99={}µs max={}µs",
        h.count,
        h.mean_us(),
        h.p50_us,
        h.p90_us,
        h.p99_us,
        h.max_us
    );
}

/// Print extsort stats: one Debug line always, phase-level breakdown
/// (including the per-phase histograms) under `--stats true`.
fn report_extsort_stats(stats: &stream::ExtSortStats, verbose: bool) {
    println!("{stats:?}");
    if !verbose {
        return;
    }
    println!(
        "phases: run-form={:.3}s merge={:.3}s io-wait={:.3}s",
        stats.run_form_secs, stats.merge_secs, stats.io_wait_secs
    );
    println!(
        "final merge: partitions={} passes={} spilled-runs={} spill-bytes={}",
        stats.partitions, stats.merge_passes, stats.spilled_runs, stats.spill_bytes
    );
    println!(
        "kernel: batches={} rows={} flushes={}",
        stats.tree.kernel_batches, stats.tree.kernel_rows, stats.tree.flushes
    );
    println!("phase histograms:");
    report_phase_hist("chunk-sort", &stats.chunk_sort);
    report_phase_hist("spill-write", &stats.spill_write);
    report_phase_hist("prefetch-wait", &stats.prefetch_wait);
}

fn start_service(o: &HashMap<String, String>) -> Result<(MergeService, &'static str)> {
    let dir = artifacts_dir(o);
    let manifest = std::path::Path::new(&dir).join("manifest.json");
    if manifest.exists() {
        let svc = MergeService::start(move || PjrtBackend::load(dir), ServiceConfig::default())?;
        Ok((svc, "pjrt"))
    } else {
        eprintln!(
            "note: {} missing — using the software backend (run `make artifacts`)",
            manifest.display()
        );
        let svc =
            MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())?;
        Ok((svc, "software"))
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        bail!(
            "usage: loms <report|netgen|goldens|validate|serve|stats|bench-net|sort|selftest> \
             [options]"
        );
    };
    let o = opts(&args[1..])?;
    match cmd.as_str() {
        "report" => {
            let which = o.get("figure").map(String::as_str).unwrap_or("all");
            let figs = if which == "all" {
                figures::all_figures()
            } else if which == "ext_plan_throughput" {
                // Wall-clock measurement — only produced on request.
                vec![figures::ext_plan_throughput()]
            } else {
                let all = figures::all_figures();
                let direct: Vec<_> = all.iter().filter(|f| f.id == which).cloned().collect();
                if direct.is_empty() {
                    let id = format!("fig{which}");
                    all.into_iter().filter(|f| f.id == id).collect()
                } else {
                    direct
                }
            };
            if figs.is_empty() {
                bail!("no figure matching {which:?}");
            }
            for f in &figs {
                println!("{}", f.to_table());
                if let Some(dir) = o.get("csv-dir") {
                    let p = f.save_csv(dir)?;
                    println!("   csv → {}\n", p.display());
                }
            }
            println!("{}", figures::mwms_note());
            Ok(())
        }
        "netgen" => {
            let d = device_from_opts(&o)?;
            d.check().map_err(anyhow::Error::msg)?;
            let text = json::to_json(&d);
            match o.get("out") {
                Some(path) => {
                    std::fs::write(path, text)?;
                    println!(
                        "wrote {path} ({} stages, {} comparators)",
                        d.depth(),
                        d.comparator_count()
                    );
                }
                None => println!("{text}"),
            }
            Ok(())
        }
        "goldens" => {
            let dir = o.get("dir").cloned().unwrap_or_else(|| "tests/golden".into());
            std::fs::create_dir_all(&dir)?;
            for (name, d) in golden_set() {
                let path = format!("{dir}/{name}.json");
                json::write_file(&d, &path)?;
                println!("wrote {path}");
            }
            Ok(())
        }
        "validate" => {
            let d = device_from_opts(&o)?;
            let t0 = Instant::now();
            if matches!(o.get("kind").map(String::as_str), Some("loms3med" | "mwmsmed")) {
                validate_median_01(&d).map_err(|e| anyhow!("{e}"))?;
            } else {
                validate_merge_01(&d).map_err(|e| anyhow!("{e}"))?;
            }
            println!(
                "{}: VALID for all inputs (sorted-0-1 exhaustive, {} patterns, {:?})",
                d.name,
                loms::sortnet::validate::merge_01_pattern_count(&d.list_sizes),
                t0.elapsed()
            );
            Ok(())
        }
        "serve" if o.contains_key("listen") => {
            let listen = o.get("listen").expect("guarded").clone();
            let workers = get_usize(&o, "workers", NetServerConfig::default().workers)?;
            let trace_sample = get_usize(&o, "trace-sample", 0)? as u64;
            let metrics_interval = get_usize(&o, "metrics-interval", 0)?;
            let mut trace_out = o
                .get("trace-file")
                .map(|p| {
                    std::fs::File::create(p)
                        .map(std::io::BufWriter::new)
                        .with_context(|| format!("creating --trace-file {p}"))
                })
                .transpose()?;
            let (svc, backend) = start_service(&o)?;
            svc.metrics().tracer().set_sample(trace_sample);
            let server = NetServer::start(
                &listen,
                svc,
                NetServerConfig { workers, ..NetServerConfig::default() },
            )?;
            println!("backend={backend} listening on {} ({workers} workers)", server.addr());
            let duration = o
                .get("duration-secs")
                .map(|v| v.parse::<u64>().with_context(|| format!("--duration-secs {v:?}")))
                .transpose()?
                .map(Duration::from_secs);
            let tick = if metrics_interval > 0 { metrics_interval as u64 } else { 10 };
            let t0 = Instant::now();
            // Periodic snapshot until the deadline (forever when none
            // was given — kill the process to stop): a one-line human
            // summary by default, the full stats document as one JSON
            // line with --metrics-interval, plus any sampled spans
            // appended to --trace-file.
            loop {
                std::thread::sleep(Duration::from_secs(tick).min(
                    duration.map_or(Duration::from_secs(tick), |d| {
                        d.saturating_sub(t0.elapsed()).max(Duration::from_millis(10))
                    }),
                ));
                let svc = server.service();
                if let Some(w) = trace_out.as_mut() {
                    let spans = svc.metrics().tracer().drain();
                    obs::write_spans_jsonl(&spans, w).context("writing --trace-file")?;
                    std::io::Write::flush(w).context("flushing --trace-file")?;
                }
                if metrics_interval > 0 {
                    let doc = obs::expo::stats_json(&svc.metrics().snapshot(), svc.pending());
                    println!("{}", doc.to_string());
                } else {
                    let s = svc.metrics().snapshot();
                    println!(
                        "conns={} frames_in={} responses={} errors={} decode_errors={} \
                         sheds={} retries={} batches={} p50={:.0}µs p99={:.0}µs",
                        s.net_connections,
                        s.net_frames_in,
                        s.net_responses,
                        s.net_errors,
                        s.net_decode_errors,
                        s.sheds,
                        s.retries,
                        s.batches,
                        s.p50_latency_us,
                        s.p99_latency_us
                    );
                }
                if duration.is_some_and(|d| t0.elapsed() >= d) {
                    break;
                }
            }
            if let Some(w) = trace_out.as_mut() {
                let spans = server.service().metrics().tracer().drain();
                obs::write_spans_jsonl(&spans, w).context("writing --trace-file")?;
                std::io::Write::flush(w).context("flushing --trace-file")?;
            }
            server.shutdown();
            println!("drained and stopped");
            Ok(())
        }
        "stats" => {
            let addr =
                o.get("addr").ok_or_else(|| anyhow!("stats requires --addr HOST:PORT"))?;
            let mut client = NetClient::connect(addr.as_str())?;
            let doc = client.stats()?;
            println!("{}", doc.to_string_pretty());
            Ok(())
        }
        "bench-net" => {
            let addr = o
                .get("addr")
                .ok_or_else(|| anyhow!("bench-net requires --addr HOST:PORT"))?;
            // Valued flag (`--smoke true`): see the --ladder-runs note.
            // Smoke mode shrinks the defaults so CI can gate on a full
            // request/response/stats round-trip in seconds.
            let smoke = o.get("smoke").map(String::as_str) == Some("true");
            let conns = get_usize(&o, "conns", if smoke { 2 } else { 8 })?;
            let inflight = get_usize(&o, "inflight", if smoke { 8 } else { 16 })?;
            let requests = get_usize(&o, "requests", if smoke { 1_000 } else { 20_000 })?;
            let seed = get_usize(&o, "seed", 0xBE7)? as u64;
            // Valued flag (`--payload true`): see the --ladder-runs note.
            let kv = o.get("payload").map(String::as_str) == Some("true");
            // `--proto v2` drives every connection over protocol v2
            // (explicit request ids, replies in completion order);
            // default is the v1 in-order pipeline.
            let v2 = match o.get("proto").map(String::as_str) {
                None | Some("v1") => false,
                Some("v2") => true,
                Some(other) => anyhow::bail!("unknown --proto {other:?} (want v1 or v2)"),
            };
            let report = net::run_load_with(addr, conns, inflight, requests, seed, kv, v2)?;
            println!(
                "mode={}{} {} conns × {} inflight: {} ok / {} errors / {} retries in {:?} \
                 ({:.0} req/s, p50 {:.0}µs, p99 {:.0}µs)",
                if kv { "key-value" } else { "key-only" },
                if v2 { " proto=v2" } else { "" },
                report.connections,
                report.inflight,
                report.ok,
                report.errors,
                report.retries,
                report.elapsed,
                report.requests_per_s(),
                report.p50_us,
                report.p99_us
            );
            for line in &report.conn_errors {
                eprintln!("note: {line}");
            }
            anyhow::ensure!(
                report.errors == 0 && report.failed_conns == 0,
                "{} responses failed the oracle check, {} connections died",
                report.errors,
                report.failed_conns
            );
            Ok(())
        }
        "serve" => {
            let n = get_usize(&o, "requests", 2000)?;
            // Valued flag (`--payload true`): see the --ladder-runs note.
            let kv = o.get("payload").map(String::as_str) == Some("true");
            let (svc, backend) = start_service(&o)?;
            let mut rng = Rng::new(1);
            let t0 = Instant::now();
            let mut rxs = Vec::with_capacity(n);
            for i in 0..n {
                let lists = if i % 4 == 3 {
                    vec![
                        rng.sorted_list(7, 1 << 20),
                        rng.sorted_list(7, 1 << 20),
                        rng.sorted_list(7, 1 << 20),
                    ]
                } else {
                    vec![rng.sorted_list(32, 1 << 20), rng.sorted_list(32, 1 << 20)]
                };
                if kv {
                    let width: usize = lists.iter().map(Vec::len).sum();
                    let payloads: Vec<u64> =
                        (0..width as u64).map(|t| ((i as u64) << 16) | t).collect();
                    rxs.push(svc.submit_kv(lists, payloads));
                } else {
                    rxs.push(svc.submit(lists));
                }
            }
            let mut ok = 0;
            for rx in rxs {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    // KV responses must carry a full payload column.
                    Ok(resp)
                        if !kv
                            || resp.payloads.as_ref().map(Vec::len) == Some(resp.merged.len()) =>
                    {
                        ok += 1
                    }
                    _ => {}
                }
            }
            let dt = t0.elapsed();
            let snap = svc.metrics().snapshot();
            println!(
                "backend={backend} mode={} served {ok}/{n} in {dt:?} ({:.0} merges/s)",
                if kv { "key-value" } else { "key-only" },
                ok as f64 / dt.as_secs_f64()
            );
            println!(
                "batches={} pad-ratio={:.2}% mean={:.0}µs p50={:.0}µs p99={:.0}µs",
                snap.batches,
                100.0 * snap.rows_padded as f64
                    / (snap.rows_real + snap.rows_padded).max(1) as f64,
                snap.mean_latency_us,
                snap.p50_latency_us,
                snap.p99_latency_us
            );
            println!(
                "stages/batch: queue-wait={:.0}µs assemble={:.1}µs execute={:.1}µs respond={:.1}µs",
                snap.queue_wait_us_mean,
                snap.assemble_us_mean,
                snap.execute_us_mean,
                snap.respond_us_mean
            );
            svc.shutdown();
            Ok(())
        }
        "sort" => {
            let engine = o.get("engine").map(String::as_str).unwrap_or("stream");
            // Valued flag (`--ladder-runs true`): the opts parser always
            // consumes the next token as the value, so a bare flag would
            // swallow the following option.
            let ladder_runs = o.get("ladder-runs").map(String::as_str) == Some("true");
            let kv = o.get("payload").map(String::as_str) == Some("true");
            if engine == "ladder" {
                // The service merge-ladder path (phases 1–2 through the
                // batched service, phase 3 on the stream engine). The
                // stream-engine options don't apply here — reject them
                // instead of silently ignoring them.
                for flag in [
                    "input",
                    "output",
                    "r",
                    "run-len",
                    "fanin",
                    "spill",
                    "sort-threads",
                    "partitions",
                    "prefetch-buf",
                    "verify-spill",
                    "ladder-runs",
                    "payload",
                    "stats",
                ] {
                    anyhow::ensure!(
                        !o.contains_key(flag),
                        "--{flag} only applies to --engine stream"
                    );
                }
                let n = get_usize(&o, "n", 1_000_000)?;
                let chunk = get_usize(&o, "chunk", 32)?;
                let (svc, backend) = start_service(&o)?;
                let mut rng = Rng::new(2);
                let data: Vec<u32> = (0..n).map(|_| rng.next_u32() >> 1).collect();
                let t0 = Instant::now();
                let (sorted, stats) = planner::external_sort(&svc, &data, chunk, 512)?;
                report_sorted(&sorted, n, &format!("backend={backend}"), t0.elapsed())?;
                println!("{stats:?}");
                return Ok(());
            }
            anyhow::ensure!(engine == "stream", "unknown --engine {engine:?} (stream|ladder)");
            let r = match o.get("r") {
                Some(v) => v.parse().with_context(|| format!("--r {v:?}"))?,
                None => default_block_r(&o),
            };
            // Valued flag (`--stats true`): see the --ladder-runs note.
            let verbose_stats = o.get("stats").map(String::as_str) == Some("true");
            let cfg = ExtSortConfig {
                run_len: get_usize(&o, "run-len", 1 << 16)?,
                r,
                max_fanin: get_usize(&o, "fanin", 64)?,
                spill_dir: o.get("spill").map(PathBuf::from),
                sort_threads: get_usize(&o, "sort-threads", 0)?,
                partitions: get_usize(&o, "partitions", 0)?,
                prefetch_buf: get_usize(&o, "prefetch-buf", 1 << 15)?,
                // Valued flag (`--verify-spill false`): see the
                // --ladder-runs note.
                verify_spill: o.get("verify-spill").map(String::as_str) != Some("false"),
            };
            if let Some(input) = o.get("input") {
                // File-to-file: bounded memory end to end.
                anyhow::ensure!(!ladder_runs, "--ladder-runs does not apply to --input sorts");
                let output = o.get("output").cloned().unwrap_or_else(|| format!("{input}.sorted"));
                let t0 = Instant::now();
                let stats = if kv {
                    // 12-byte LE (u32 key, u64 payload) records in and out.
                    stream::extsort_kv_file(Path::new(input), Path::new(&output), &cfg)?
                } else {
                    stream::extsort_file(Path::new(input), Path::new(&output), &cfg)?
                };
                let dt = t0.elapsed();
                println!(
                    "sorted {} {} (R={r}) {input} → {output} in {dt:?} ({:.2} Mkeys/s)",
                    stats.keys,
                    if kv { "key-value pairs" } else { "keys" },
                    stats.keys as f64 / dt.as_secs_f64() / 1e6
                );
                report_extsort_stats(&stats, verbose_stats);
                return Ok(());
            }
            let n = get_usize(&o, "n", 1_000_000)?;
            let mut rng = Rng::new(2);
            if kv {
                anyhow::ensure!(!ladder_runs, "--ladder-runs does not apply to --payload sorts");
                let keys: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
                let pays: Vec<u64> = (0..n as u64).collect();
                let t0 = Instant::now();
                let (sorted, sorted_pays, stats) = stream::extsort_kv(&keys, &pays, &cfg)?;
                let dt = t0.elapsed();
                anyhow::ensure!(sorted_pays.len() == sorted.len(), "lost payloads");
                report_sorted(&sorted, n, &format!("stream key-value (R={r})"), dt)?;
                report_extsort_stats(&stats, verbose_stats);
                return Ok(());
            }
            // The pure stream engine handles the full u32 domain; the
            // ladder run-former goes through the service, whose keys
            // must stay below the PAD sentinel.
            let shift = u32::from(ladder_runs);
            let data: Vec<u32> = (0..n).map(|_| rng.next_u32() >> shift).collect();
            let (sorted, stats, dt) = if ladder_runs {
                let (svc, backend) = start_service(&o)?;
                let chunk = get_usize(&o, "chunk", 32)?;
                let t0 = Instant::now();
                let (sorted, stats) = stream::extsort_with(
                    &data,
                    &cfg,
                    &RunFormer::Ladder { service: &svc, chunk, max_network: 512 },
                )?;
                let dt = t0.elapsed();
                println!("runs formed through the {backend} merge ladder");
                svc.shutdown();
                (sorted, stats, dt)
            } else {
                let t0 = Instant::now();
                let (sorted, stats) = stream::extsort(&data, &cfg)?;
                (sorted, stats, t0.elapsed())
            };
            report_sorted(&sorted, n, &format!("stream (R={r})"), dt)?;
            report_extsort_stats(&stats, verbose_stats);
            Ok(())
        }
        "selftest" => {
            validate_merge_01(&lomsnet::loms_2way(8, 8, 2)).map_err(|e| anyhow!("{e}"))?;
            validate_merge_01(&lomsnet::loms_kway(&[7, 7, 7])).map_err(|e| anyhow!("{e}"))?;
            let (svc, backend) = start_service(&o)?;
            let resp = svc.merge_blocking(vec![vec![1, 3, 5], vec![2, 4, 6]])?;
            anyhow::ensure!(resp.merged == vec![1, 2, 3, 4, 5, 6]);
            println!("selftest OK (backend={backend})");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}"),
    }
}
