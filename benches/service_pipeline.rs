//! End-to-end service benchmark: the perf trajectory of the serving
//! data path, emitted as `BENCH_service.json` (requests/s, p50/p99
//! latency, payload copies per batch).
//!
//! Three variants of the same ragged 32+32 workload against the
//! `loms2_up32_dn32_b256` software artifact:
//!
//! 1. `old_assemble_then_execute` — the pre-tile-direct data path:
//!    request lists → padded list-major/row-major assembly → row-major
//!    lane batch (tile scatter + whole-batch output vector) →
//!    per-response `to_vec` — four payload copies per batch.
//! 2. `tile_direct` — [`Backend::execute_direct`]: request slices →
//!    transposed lane tile → per-response buffers — two copies.
//! 3. `tile_direct_pipelined` — the full [`MergeService`] round trip:
//!    the tile-direct executor overlapped with dynamic batching on the
//!    engine thread (depth-1 pipeline), latency percentiles from the
//!    service's own histogram.
//! 4. `tile_direct_kv` — [`Backend::execute_direct_kv`]: the same
//!    requests with one `u64` payload per key, keys through the packed
//!    rank-then-permute tiles, payloads gathered once per row. The
//!    delta to `tile_direct` is the cost of carrying payloads.
//! 5. `kv_pipelined` — the full service round trip in key-value mode
//!    (`submit_kv`), batched per `(artifact, kv)` queue.
//! 6./7. `pipelined_obs_on` / `pipelined_obs_off` — the pipelined
//!    round trip with detail recording (histograms + span retention)
//!    on vs off, best of 3; the harness asserts the throughput delta
//!    stays within 3% (the "cheap enough to leave on" contract).
//!
//! For the backend-level variants, each request's latency is its
//! batch's service time, so percentiles are taken over per-batch
//! durations. CI runs this harness in smoke mode (`--smoke` /
//! `BENCH_SMOKE=1`: few batches) and uploads the JSON; run
//! `cargo bench --bench service_pipeline` for full-size numbers.

use loms::coordinator::{Backend, MergeService, ServiceConfig, SoftwareBackend};
use loms::obs::percentile_us;
use loms::runtime::ArtifactMeta;
use loms::util::Rng;
use std::time::Instant;

const ARTIFACT: &str = "loms2_up32_dn32_b256";

struct Variant {
    name: &'static str,
    mode: &'static str,
    requests_per_s: f64,
    p50_latency_us: f64,
    p99_latency_us: f64,
    copies_per_batch: usize,
}

/// Ragged request batches for the artifact shape.
fn workload(rng: &mut Rng, meta: &ArtifactMeta, batches: usize) -> Vec<Vec<Vec<Vec<u32>>>> {
    (0..batches)
        .map(|_| {
            (0..meta.batch)
                .map(|_| {
                    meta.list_sizes
                        .iter()
                        .map(|&cap| {
                            let len = rng.range(1, cap + 1);
                            rng.sorted_list(len, 1 << 22)
                        })
                        .collect()
                })
                .collect()
        })
        .collect()
}

fn batch_percentiles(durations_us: Vec<f64>) -> (f64, f64) {
    // Same log-linear histogram definition as the service's own
    // latency percentiles, so every p50/p99 in the JSON is comparable.
    (percentile_us(&durations_us, 0.50), percentile_us(&durations_us, 0.99))
}

/// One full pipelined-service round trip over a fresh workload with
/// detail recording (histograms + span retention) on or off. Returns
/// `(requests/s, p50 µs, p99 µs)` — the percentiles read 0 with detail
/// off, since the histograms are the thing being switched.
fn run_pipelined(
    reqs: Vec<Vec<Vec<Vec<u32>>>>,
    n_requests: usize,
    detail: bool,
) -> (f64, f64, f64) {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .unwrap();
    svc.metrics().set_detail(detail);
    svc.merge_blocking(vec![vec![1, 2], vec![3, 4]]).unwrap();
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    for batch_reqs in reqs {
        for r in batch_reqs {
            rxs.push(svc.submit(r));
        }
    }
    for rx in rxs {
        rx.recv().expect("service response");
    }
    let total = t0.elapsed();
    let snap = svc.metrics().snapshot();
    svc.shutdown();
    (n_requests as f64 / total.as_secs_f64(), snap.p50_latency_us, snap.p99_latency_us)
}

fn main() {
    let batches: usize = std::env::var("BENCH_BATCHES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if loms::bench::smoke_mode() { 6 } else { 40 });
    let mut rng = Rng::new(0xB5EC);
    let mut backend = SoftwareBackend::default_set();
    let meta = backend.artifacts().into_iter().find(|m| &*m.name == ARTIFACT).unwrap();
    let reqs = workload(&mut rng, &meta, batches);
    let n_requests = batches * meta.batch;

    // Warm the plan + lane-plan caches outside the timed region.
    {
        let rows: Vec<&[Vec<u32>]> = reqs[0].iter().map(|r| r.as_slice()).collect();
        let mut merged: Vec<Vec<u32>> =
            reqs[0].iter().map(|r| vec![0u32; r.iter().map(Vec::len).sum()]).collect();
        let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
        backend.execute_direct(ARTIFACT, &rows, &mut outs).unwrap();
    }

    // Variant 1: assemble-then-execute (the old four-copy data path,
    // via the shared reference implementation on the backend).
    let mut durations = Vec::with_capacity(batches);
    let t_old = Instant::now();
    for batch_reqs in &reqs {
        let t0 = Instant::now();
        let responses = backend.execute_padded_reference(ARTIFACT, batch_reqs).unwrap();
        std::hint::black_box(&responses);
        durations.push(t0.elapsed().as_nanos() as f64 / 1_000.0);
    }
    let old_total = t_old.elapsed();
    let (old_p50, old_p99) = batch_percentiles(durations);

    // Variant 2: tile-direct (two copies, no padding rows).
    let mut durations = Vec::with_capacity(batches);
    let t_direct = Instant::now();
    for batch_reqs in &reqs {
        let t0 = Instant::now();
        let rows: Vec<&[Vec<u32>]> = batch_reqs.iter().map(|r| r.as_slice()).collect();
        let mut merged: Vec<Vec<u32>> = batch_reqs
            .iter()
            .map(|r| vec![0u32; r.iter().map(Vec::len).sum()])
            .collect();
        let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
        backend.execute_direct(ARTIFACT, &rows, &mut outs).unwrap();
        std::hint::black_box(&merged);
        durations.push(t0.elapsed().as_nanos() as f64 / 1_000.0);
    }
    let direct_total = t_direct.elapsed();
    let (direct_p50, direct_p99) = batch_percentiles(durations);

    // Variant 4 (timed here, reported after): tile-direct key-value —
    // the same requests with one u64 payload per key. Payload columns
    // are prepared off the clock; the timed region is the engine.
    let kv_pays: Vec<Vec<Vec<u64>>> = reqs
        .iter()
        .map(|batch_reqs| {
            batch_reqs
                .iter()
                .map(|r| (0..r.iter().map(Vec::len).sum::<usize>() as u64).collect())
                .collect()
        })
        .collect();
    let mut durations = Vec::with_capacity(batches);
    let t_kv = Instant::now();
    for (batch_reqs, batch_pays) in reqs.iter().zip(&kv_pays) {
        let t0 = Instant::now();
        let rows: Vec<&[Vec<u32>]> = batch_reqs.iter().map(|r| r.as_slice()).collect();
        let pays: Vec<&[u64]> = batch_pays.iter().map(|p| p.as_slice()).collect();
        let mut merged: Vec<Vec<u32>> = batch_reqs
            .iter()
            .map(|r| vec![0u32; r.iter().map(Vec::len).sum()])
            .collect();
        let mut merged_pays: Vec<Vec<u64>> =
            merged.iter().map(|m| vec![0u64; m.len()]).collect();
        let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
        let mut pay_outs: Vec<&mut [u64]> =
            merged_pays.iter_mut().map(|v| v.as_mut_slice()).collect();
        backend.execute_direct_kv(ARTIFACT, &rows, &pays, &mut outs, &mut pay_outs).unwrap();
        std::hint::black_box((&merged, &merged_pays));
        durations.push(t0.elapsed().as_nanos() as f64 / 1_000.0);
    }
    let kv_total = t_kv.elapsed();
    let (kv_p50, kv_p99) = batch_percentiles(durations);

    // Variant 3: the full pipelined service round trip.
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .unwrap();
    // Warm the service-side plan caches off the clock.
    svc.merge_blocking(vec![vec![1, 2], vec![3, 4]]).unwrap();
    // Variant 3 is the last user of the workload, so the requests are
    // moved into `submit` — no payload clone inside the timed region
    // (variants 1–2 only borrow `reqs`).
    let t_svc = Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    for batch_reqs in reqs {
        for r in batch_reqs {
            rxs.push(svc.submit(r));
        }
    }
    for rx in rxs {
        rx.recv().expect("service response");
    }
    let svc_total = t_svc.elapsed();
    let snap = svc.metrics().snapshot();
    svc.shutdown();

    // Variant 5: the full service round trip in key-value mode — its
    // own service instance so the latency histogram holds KV requests
    // only.
    let svc_kv =
        MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
            .unwrap();
    svc_kv.merge_blocking_kv(vec![vec![1, 2], vec![3, 4]], vec![10, 20, 30, 40]).unwrap();
    let kv_reqs = workload(&mut rng, &meta, batches);
    let t_svckv = Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    for batch_reqs in kv_reqs {
        for r in batch_reqs {
            let width: usize = r.iter().map(Vec::len).sum();
            rxs.push(svc_kv.submit_kv(r, (0..width as u64).collect()));
        }
    }
    for rx in rxs {
        let resp = rx.recv().expect("service KV response");
        assert_eq!(resp.payloads.as_ref().map(Vec::len), Some(resp.merged.len()));
    }
    let svckv_total = t_svckv.elapsed();
    let snap_kv = svc_kv.metrics().snapshot();
    svc_kv.shutdown();

    // Obs-overhead guard: the same pipelined workload with detail
    // recording (histograms + span retention) on vs off, best of 3
    // runs each so scheduler noise doesn't fail the gate. The contract
    // ("cheap enough to leave on") is a throughput delta within 3% —
    // relaxed in smoke mode, where runs are far too short to separate
    // recording cost from noise.
    let (mut on, mut off) = ((0.0f64, 0.0, 0.0), (0.0f64, 0.0, 0.0));
    for _ in 0..3 {
        let r = run_pipelined(workload(&mut rng, &meta, batches), n_requests, true);
        if r.0 > on.0 {
            on = r;
        }
        let r = run_pipelined(workload(&mut rng, &meta, batches), n_requests, false);
        if r.0 > off.0 {
            off = r;
        }
    }
    let overhead = (off.0 - on.0) / off.0;
    let tolerance = if loms::bench::smoke_mode() { 0.25 } else { 0.03 };
    println!(
        "obs overhead: on={:.0} req/s off={:.0} req/s delta={:+.2}% (tolerance {:.0}%)",
        on.0,
        off.0,
        100.0 * overhead,
        100.0 * tolerance
    );
    assert!(
        overhead <= tolerance,
        "observability overhead {:.2}% exceeds {:.0}% (on={:.0} off={:.0} req/s)",
        100.0 * overhead,
        100.0 * tolerance,
        on.0,
        off.0
    );

    let variants = [
        Variant {
            name: "old_assemble_then_execute",
            mode: "key_only",
            requests_per_s: n_requests as f64 / old_total.as_secs_f64(),
            p50_latency_us: old_p50,
            p99_latency_us: old_p99,
            copies_per_batch: 4,
        },
        Variant {
            name: "tile_direct",
            mode: "key_only",
            requests_per_s: n_requests as f64 / direct_total.as_secs_f64(),
            p50_latency_us: direct_p50,
            p99_latency_us: direct_p99,
            copies_per_batch: 2,
        },
        Variant {
            name: "tile_direct_pipelined",
            mode: "key_only",
            requests_per_s: n_requests as f64 / svc_total.as_secs_f64(),
            p50_latency_us: snap.p50_latency_us,
            p99_latency_us: snap.p99_latency_us,
            copies_per_batch: 2,
        },
        Variant {
            name: "tile_direct_kv",
            mode: "key_value",
            requests_per_s: n_requests as f64 / kv_total.as_secs_f64(),
            p50_latency_us: kv_p50,
            p99_latency_us: kv_p99,
            // Keys: in + out, as tile_direct. The payload column moves
            // exactly once per row (permutation gather).
            copies_per_batch: 3,
        },
        Variant {
            name: "kv_pipelined",
            mode: "key_value",
            requests_per_s: n_requests as f64 / svckv_total.as_secs_f64(),
            p50_latency_us: snap_kv.p50_latency_us,
            p99_latency_us: snap_kv.p99_latency_us,
            copies_per_batch: 3,
        },
        Variant {
            name: "pipelined_obs_on",
            mode: "key_only",
            requests_per_s: on.0,
            p50_latency_us: on.1,
            p99_latency_us: on.2,
            copies_per_batch: 2,
        },
        Variant {
            name: "pipelined_obs_off",
            mode: "key_only",
            requests_per_s: off.0,
            p50_latency_us: off.1,
            p99_latency_us: off.2,
            copies_per_batch: 2,
        },
    ];
    for v in &variants {
        println!(
            "{:<28} [{:>9}] {:>12.0} req/s   p50 {:>9.1}µs   p99 {:>9.1}µs   {} copies/batch",
            v.name, v.mode, v.requests_per_s, v.p50_latency_us, v.p99_latency_us,
            v.copies_per_batch
        );
    }
    println!(
        "service stages/batch: queue-wait={:.0}µs assemble={:.1}µs execute={:.1}µs respond={:.1}µs",
        snap.queue_wait_us_mean, snap.assemble_us_mean, snap.execute_us_mean, snap.respond_us_mean
    );

    let rows: Vec<String> = variants
        .iter()
        .map(|v| {
            format!(
                "    {{\"name\": \"{}\", \"mode\": \"{}\", \"requests_per_s\": {:.0}, \
                 \"p50_latency_us\": {:.1}, \"p99_latency_us\": {:.1}, \
                 \"copies_per_batch\": {}}}",
                v.name, v.mode, v.requests_per_s, v.p50_latency_us, v.p99_latency_us,
                v.copies_per_batch
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"service_pipeline\",\n  \"artifact\": \"{ARTIFACT}\",\n  \
         \"batch\": {},\n  \"requests\": {},\n  \"variants\": [\n{}\n  ]\n}}\n",
        meta.batch,
        n_requests,
        rows.join(",\n")
    );
    std::fs::write("BENCH_service.json", &json).expect("write BENCH_service.json");
    println!("wrote BENCH_service.json");
}
