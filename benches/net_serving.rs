//! Networked serving benchmark: the connection-scaling curve, emitted
//! as `BENCH_net.json`.
//!
//! One software-backed [`MergeService`] behind a [`NetServer`] (32
//! dispatch workers, readiness-loop front-end) on an ephemeral
//! loopback port. Variants over the same ragged 32+32 workload
//! ([`loms::net::client::workload_lists`]):
//!
//! * `in_process` — the baseline: requests submitted straight into the
//!   service from this process (no sockets, no frames), latency
//!   measured per request with the same pipelined window the network
//!   clients use — so the delta to the next rows is purely transport.
//! * `net_1conn` / `net_8conn` / `net_32conn` / `net_256conn` /
//!   `net_1024conn` — the framed TCP path at increasing connection
//!   counts, each connection keeping `INFLIGHT` requests pipelined.
//!   The interesting rows are the ones where connections vastly
//!   outnumber the 32 dispatch workers: a thread-per-connection server
//!   would starve there; the readiness loop must hold throughput flat.
//!   The 1024-connection row runs in full mode only (smoke stops at
//!   256 to keep CI under budget).
//! * `net_8conn_kv` — the same wire path carrying v1.1 key-value
//!   frames (one `u64` payload per key, both directions); the delta to
//!   `net_8conn` is the payload's wire + permute cost.
//! * `net_32conn_v2` — the same wire path over protocol v2 (explicit
//!   request ids, replies matched by id in completion order); the
//!   delta to `net_32conn` is the id bookkeeping, which should be
//!   noise.
//!
//! Every response (all variants) is verified byte-exact against a sort
//! oracle — a bench run that returns wrong bytes panics rather than
//! reporting a throughput. CI runs this harness in smoke mode
//! (`--smoke` / `BENCH_SMOKE=1`) and uploads the JSON; run
//! `cargo bench --bench net_serving` for full-size numbers.

use loms::coordinator::{MergeService, ServiceConfig, SoftwareBackend};
use loms::net::client::{percentile_us, workload_lists};
use loms::net::{run_load_with, NetServer, NetServerConfig};
use loms::util::Rng;
use std::collections::VecDeque;
use std::time::Instant;

const INFLIGHT: usize = 16;

struct Variant {
    name: String,
    /// Concurrent TCP connections (0 for the in-process baseline).
    conns: usize,
    requests_per_s: f64,
    p50_latency_us: f64,
    p99_latency_us: f64,
}

/// The in-process baseline: same workload, same pipelined window, no
/// wire. Returns (req/s, p50 µs, p99 µs).
fn run_in_process(svc: &MergeService, requests: usize, seed: u64) -> Variant {
    let mut rng = Rng::new(seed);
    let mut pending: VecDeque<(std::sync::mpsc::Receiver<_>, Vec<u32>, Instant)> =
        VecDeque::new();
    let mut lat_us = Vec::with_capacity(requests);
    let t0 = Instant::now();
    for _ in 0..requests {
        let lists = workload_lists(&mut rng);
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        pending.push_back((svc.submit(lists), want, Instant::now()));
        if pending.len() >= INFLIGHT {
            let (rx, want, sent) = pending.pop_front().unwrap();
            let resp = rx.recv().expect("in-process response");
            assert_eq!(resp.merged, want, "in-process oracle mismatch");
            lat_us.push(sent.elapsed().as_nanos() as f64 / 1_000.0);
        }
    }
    while let Some((rx, want, sent)) = pending.pop_front() {
        let resp = rx.recv().expect("in-process response");
        assert_eq!(resp.merged, want, "in-process oracle mismatch");
        lat_us.push(sent.elapsed().as_nanos() as f64 / 1_000.0);
    }
    let dt = t0.elapsed();
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Variant {
        name: "in_process".into(),
        conns: 0,
        requests_per_s: requests as f64 / dt.as_secs_f64(),
        p50_latency_us: percentile_us(&lat_us, 0.50),
        p99_latency_us: percentile_us(&lat_us, 0.99),
    }
}

/// One wire variant: drive `requests` through `conns` connections and
/// hold the run to the oracle (zero errors, zero dead connections).
fn run_wire(
    addr: &str,
    name: String,
    conns: usize,
    requests: usize,
    seed: u64,
    kv: bool,
    v2: bool,
) -> Variant {
    let report =
        run_load_with(addr, conns, INFLIGHT, requests, seed, kv, v2).expect("load run");
    assert_eq!(report.errors, 0, "{name}: net oracle mismatches");
    assert_eq!(
        report.failed_conns, 0,
        "{name}: dead connections: {:?}",
        report.conn_errors
    );
    Variant {
        name,
        conns,
        requests_per_s: report.requests_per_s(),
        p50_latency_us: report.p50_us,
        p99_latency_us: report.p99_us,
    }
}

fn main() {
    let smoke = loms::bench::smoke_mode();
    let requests: usize = std::env::var("BENCH_NET_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 2_000 } else { 40_000 });
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .expect("service");
    // Warm the plan caches off the clock.
    svc.merge_blocking(vec![vec![1, 2], vec![3, 4]]).expect("warmup");

    let mut variants = vec![run_in_process(&svc, requests, 0xBE2C)];

    // Same service, now behind the wire. 32 workers against up to 1024
    // connections: the scaling curve's right edge is the regime the
    // readiness loop exists for.
    let server = NetServer::start(
        "127.0.0.1:0",
        svc,
        NetServerConfig { workers: 32, ..NetServerConfig::default() },
    )
    .expect("server");
    let addr = server.addr().to_string();
    let curve: &[usize] = if smoke { &[1, 8, 32, 256] } else { &[1, 8, 32, 256, 1024] };
    for &conns in curve {
        variants.push(run_wire(
            &addr,
            format!("net_{conns}conn"),
            conns,
            requests,
            0x9E7 + conns as u64,
            false,
            false,
        ));
    }
    // The same wire path carrying v1.1 key-value frames.
    variants.push(run_wire(&addr, "net_8conn_kv".into(), 8, requests, 0xA11E, true, false));
    // The same wire path over protocol v2 (explicit request ids).
    variants.push(run_wire(&addr, "net_32conn_v2".into(), 32, requests, 0xF2BD, false, true));
    let snap = server.service().metrics().snapshot();
    server.shutdown();

    for v in &variants {
        println!(
            "{:<14} {:>12.0} req/s   p50 {:>9.1}µs   p99 {:>9.1}µs",
            v.name, v.requests_per_s, v.p50_latency_us, v.p99_latency_us
        );
    }
    println!(
        "server totals: conns={} frames_in={} responses={} errors={}",
        snap.net_connections, snap.net_frames_in, snap.net_responses, snap.net_errors
    );

    let rows: Vec<String> = variants
        .iter()
        .map(|v| {
            format!(
                "    {{\"name\": \"{}\", \"conns\": {}, \"requests_per_s\": {:.0}, \
                 \"p50_latency_us\": {:.1}, \"p99_latency_us\": {:.1}}}",
                v.name, v.conns, v.requests_per_s, v.p50_latency_us, v.p99_latency_us
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"net_serving\",\n  \"requests_per_variant\": {requests},\n  \
         \"inflight_per_conn\": {INFLIGHT},\n  \"workers\": 32,\n  \"variants\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_net.json", &json).expect("write BENCH_net.json");
    println!("wrote BENCH_net.json");
}
