//! Regenerates Figs. 16 & 17 (32-bit Ultrascale+ 2insLUT: Bitonic vs
//! S2MS vs LOMS 2/4/8-col up to 256 outputs) plus the Fig.-10 fit
//! matrix, including the paper's headline anchor (2.24 ns / 2.63×).

use loms::bench::figures;

fn main() {
    for f in [figures::fig10(), figures::fig16(), figures::fig17()] {
        println!("{}", f.to_table());
        let p = f.save_csv("bench_out").expect("csv");
        println!("   csv → {}\n", p.display());
    }
}
