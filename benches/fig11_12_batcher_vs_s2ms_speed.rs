//! Regenerates Figs. 11 & 12 (Batcher vs S2MS propagation delay, 8-bit
//! and 32-bit, both FPGAs) and times the software execution of the same
//! devices (ns/merge on this host).

use loms::bench::{figures, timing};
use loms::sortnet::exec::{ExecMode, ExecScratch};
use loms::sortnet::{batcher, s2ms};
use loms::util::Rng;

fn main() {
    for f in [figures::fig11(), figures::fig12()] {
        println!("{}", f.to_table());
        let p = f.save_csv("bench_out").expect("csv");
        println!("   csv → {}\n", p.display());
    }
    // Host-side execution throughput of the same networks.
    let mut rng = Rng::new(1);
    for m in [8usize, 16, 32] {
        for (label, d) in [
            (format!("oem up{m}/dn{m} software exec"), batcher::odd_even_merge(m)),
            (format!("s2ms up{m}/dn{m} software exec"), s2ms::s2ms(m, m)),
        ] {
            let a = rng.sorted_list(m, 1 << 20);
            let b = rng.sorted_list(m, 1 << 20);
            let mut v = d.load_inputs(&[a, b]);
            let mut scratch = ExecScratch::new();
            let base = v.clone();
            let meas = timing::bench(&label, || {
                v.copy_from_slice(&base);
                scratch.run(&d, &mut v, ExecMode::Fast, None).unwrap();
                std::hint::black_box(&v);
            });
            println!("{}", meas.row());
        }
    }
}
