//! Regenerates Figs. 14 & 15 (32-bit Versal 4insLUT: Bitonic vs S2MS vs
//! 2-col LOMS — speed and LUTs for small devices).

use loms::bench::figures;

fn main() {
    for f in [figures::fig14(), figures::fig15()] {
        println!("{}", f.to_table());
        let p = f.save_csv("bench_out").expect("csv");
        println!("   csv → {}\n", p.display());
    }
}
