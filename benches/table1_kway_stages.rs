//! Regenerates Table 1 (column/row sorts per k) with exhaustive
//! validation of our reconstruction up to k = 12 (3^k sorted-0-1
//! patterns; k = 13, 14 are claimed-only — minutes of validation).

use loms::bench::figures;

fn main() {
    let deep = std::env::args().any(|a| a == "--deep");
    // --deep > default > --smoke: exhaustive 0-1 validation is 3^k, so
    // smoke stops at k = 10 (still ~59k patterns at the top).
    let hi = if deep {
        14
    } else if loms::bench::smoke_mode() {
        10
    } else {
        12
    };
    let f = figures::table1_to(hi);
    println!("{}", f.to_table());
    let p = f.save_csv("bench_out").expect("csv");
    println!("   csv → {}", p.display());
}
