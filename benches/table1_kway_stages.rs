//! Regenerates Table 1 (column/row sorts per k) with exhaustive
//! validation of our reconstruction up to k = 12 (3^k sorted-0-1
//! patterns; k = 13, 14 are claimed-only — minutes of validation).

use loms::bench::figures;

fn main() {
    let deep = std::env::args().any(|a| a == "--deep");
    let f = figures::table1_to(if deep { 14 } else { 12 });
    println!("{}", f.to_table());
    let p = f.save_csv("bench_out").expect("csv");
    println!("   csv → {}", p.display());
}
