//! End-to-end runtime benchmark: raw PJRT batch execution per artifact
//! and full service round-trips (the coordinator-overhead measurement
//! EXPERIMENTS.md §Perf tracks). Skips PJRT parts when artifacts are
//! missing.

use loms::bench::timing;
use loms::coordinator::{MergeService, PjrtBackend, ServiceConfig, SoftwareBackend};
use loms::runtime::Runtime;
use loms::util::Rng;
use std::time::Instant;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    let have_artifacts = dir.join("manifest.json").exists();
    if have_artifacts {
        let mut rt = Runtime::load(&dir).expect("runtime");
        let mut rng = Rng::new(11);
        for name in rt.names() {
            let meta = rt.executable_mut(&name).unwrap().meta.clone();
            let lists: Vec<Vec<u32>> = meta
                .list_sizes
                .iter()
                .map(|&s| {
                    let mut flat = Vec::with_capacity(meta.batch * s);
                    for _ in 0..meta.batch {
                        flat.extend(rng.sorted_list(s, 1 << 22));
                    }
                    flat
                })
                .collect();
            let exe = rt.executable_mut(&name).unwrap();
            let meas = timing::bench(&format!("pjrt exec {name}"), || {
                std::hint::black_box(exe.execute_batch(&lists).unwrap());
            });
            let rows_per_s = meta.batch as f64 / (meas.mean_ns / 1e9);
            println!("{}   ({rows_per_s:.0} merges/s raw)", meas.row());
        }
    } else {
        eprintln!("artifacts missing — skipping raw PJRT benches");
    }

    // Service round-trip throughput (dynamic batching + verification).
    let (svc, backend) = if have_artifacts {
        let d = dir.clone();
        (MergeService::start(move || PjrtBackend::load(d), ServiceConfig::default()).unwrap(), "pjrt")
    } else {
        (
            MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
                .unwrap(),
            "software",
        )
    };
    let mut rng = Rng::new(12);
    // Smoke mode keeps the same round-trip path at 1/10th the volume.
    let n = if loms::bench::smoke_mode() { 2_000usize } else { 20_000usize };
    // Pre-generate the workload: the timer measures the service, not rng.
    let workload: Vec<Vec<Vec<u32>>> = (0..n)
        .map(|_| vec![rng.sorted_list(32, 1 << 22), rng.sorted_list(32, 1 << 22)])
        .collect();
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n);
    for lists in workload {
        rxs.push(svc.submit(lists));
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    let dt = t0.elapsed();
    let snap = svc.metrics().snapshot();
    println!(
        "service({backend}) 32+32 merge round-trips: {:.0} merges/s (n={n}, batches={}, p50={:.0}µs p99={:.0}µs)",
        n as f64 / dt.as_secs_f64(),
        snap.batches,
        snap.p50_latency_us,
        snap.p99_latency_us
    );
    svc.shutdown();
}
