//! Software-execution throughput of every device family — the host-side
//! analogue of the paper's device comparison, plus scaling over sizes.
//!
//! Every device is measured twice, side by side: the enum-tree
//! interpreter (`ExecScratch::run`) and the lowered IR
//! (`CompiledPlan::run_row`). A final section measures the software
//! backend's batch shape (`loms2_up32_dn32_b256`): the old per-row
//! interpreter loop vs `CompiledPlan::run_batch` in one call.

use loms::bench::timing;
use loms::sortnet::exec::{ExecMode, ExecScratch};
use loms::sortnet::plan::{CompiledPlan, PlanScratch};
use loms::sortnet::{batcher, loms as lm, s2ms};
use loms::util::Rng;

fn main() {
    let mut rng = Rng::new(9);
    for outs in [16usize, 64, 256] {
        let m = outs / 2;
        let devices = vec![
            (format!("batcher-oem {outs}-out"), batcher::odd_even_merge(m)),
            (format!("batcher-bitonic {outs}-out"), batcher::bitonic_merge(m)),
            (format!("s2ms {outs}-out"), s2ms::s2ms(m, m)),
            (format!("loms-2col {outs}-out"), lm::loms_2way(m, m, 2)),
            (format!("loms-8col {outs}-out"), lm::loms_2way(m, m, 8)),
        ];
        for (label, d) in devices {
            let a = rng.sorted_list(m, 1 << 20);
            let b = rng.sorted_list(m, 1 << 20);
            let mut v = d.load_inputs(&[a, b]);
            let base = v.clone();
            let mut scratch = ExecScratch::new();
            let interp = timing::bench(&format!("{label} [interp]"), || {
                v.copy_from_slice(&base);
                scratch.run(&d, &mut v, ExecMode::Fast, None).unwrap();
                std::hint::black_box(&v);
            });
            println!("{}", interp.row());
            let plan = CompiledPlan::compile(&d).expect("valid device");
            let mut ps = PlanScratch::new();
            let planned = timing::bench(&format!("{label} [plan]"), || {
                v.copy_from_slice(&base);
                plan.run_row(&mut v, ExecMode::Fast, None, &mut ps).unwrap();
                std::hint::black_box(&v);
            });
            println!("{}   ({:.2}x vs interp)", planned.row(), interp.mean_ns / planned.mean_ns);
        }
    }

    // The software backend's batch shape: loms2_up32_dn32_b256. The old
    // execute loop re-dispatched the device per row; run_batch executes
    // the whole row-major batch through the lowered IR in one call.
    let d = lm::loms_2way(32, 32, 2);
    let batch = 256usize;
    let sizes = [32usize, 32];
    let lists: Vec<Vec<u32>> = sizes
        .iter()
        .map(|&s| {
            let mut flat = Vec::with_capacity(batch * s);
            for _ in 0..batch {
                flat.extend(rng.sorted_list(s, 1 << 20));
            }
            flat
        })
        .collect();
    let total = d.n;
    let mut out = Vec::with_capacity(batch * total);

    let mut scratch = ExecScratch::new();
    let mut v = vec![0u32; d.n];
    let per_row = timing::bench("loms2_up32_dn32_b256 [interp per-row]", || {
        out.clear();
        for row in 0..batch {
            for (l, &s) in sizes.iter().enumerate() {
                let slice = &lists[l][row * s..(row + 1) * s];
                for (i, &x) in slice.iter().enumerate() {
                    v[d.input_map[l][i]] = x;
                }
            }
            scratch.run(&d, &mut v, ExecMode::Fast, None).unwrap();
            out.extend(d.output_perm.iter().map(|&p| v[p]));
        }
        std::hint::black_box(&out);
    });
    println!("{}", per_row.row());

    let plan = CompiledPlan::compile_auto(&d).expect("valid device");
    let mut ps = PlanScratch::new();
    let batched = timing::bench("loms2_up32_dn32_b256 [plan run_batch]", || {
        out.clear();
        plan.run_batch(&lists, batch, ExecMode::Fast, &mut ps, &mut out).unwrap();
        std::hint::black_box(&out);
    });
    println!("{}", batched.row());
    println!(
        "run_batch speedup over per-row interpreter: {:.2}x (pruned={}, {} ops, arena {} u32)",
        per_row.mean_ns / batched.mean_ns,
        plan.is_pruned(),
        plan.op_count(),
        plan.arena_len()
    );

    // Reference: std two-pointer merge of the same sizes.
    for outs in [16usize, 64, 256] {
        let m = outs / 2;
        let a = rng.sorted_list(m, 1 << 20);
        let b = rng.sorted_list(m, 1 << 20);
        let mut out = vec![0u32; outs];
        let meas = timing::bench(&format!("std two-pointer merge {outs}-out"), || {
            let (mut i, mut j, mut t) = (0, 0, 0);
            while i < a.len() && j < b.len() {
                if a[i] <= b[j] {
                    out[t] = a[i];
                    i += 1;
                } else {
                    out[t] = b[j];
                    j += 1;
                }
                t += 1;
            }
            out[t..t + a.len() - i].copy_from_slice(&a[i..]);
            let t2 = t + a.len() - i;
            out[t2..].copy_from_slice(&b[j..]);
            std::hint::black_box(&out);
        });
        println!("{}", meas.row());
    }
}
