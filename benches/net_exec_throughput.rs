//! Software-execution throughput of every device family — the host-side
//! analogue of the paper's device comparison, plus scaling over sizes.

use loms::bench::timing;
use loms::sortnet::exec::{ExecMode, ExecScratch};
use loms::sortnet::{batcher, loms as lm, s2ms};
use loms::util::Rng;

fn main() {
    let mut rng = Rng::new(9);
    let mut rows = Vec::new();
    for outs in [16usize, 64, 256] {
        let m = outs / 2;
        let devices = vec![
            (format!("batcher-oem {outs}-out"), batcher::odd_even_merge(m)),
            (format!("batcher-bitonic {outs}-out"), batcher::bitonic_merge(m)),
            (format!("s2ms {outs}-out"), s2ms::s2ms(m, m)),
            (format!("loms-2col {outs}-out"), lm::loms_2way(m, m, 2)),
            (format!("loms-8col {outs}-out"), lm::loms_2way(m, m, 8)),
        ];
        for (label, d) in devices {
            let a = rng.sorted_list(m, 1 << 20);
            let b = rng.sorted_list(m, 1 << 20);
            let mut v = d.load_inputs(&[a, b]);
            let base = v.clone();
            let mut scratch = ExecScratch::new();
            let meas = timing::bench(&label, || {
                v.copy_from_slice(&base);
                scratch.run(&d, &mut v, ExecMode::Fast, None).unwrap();
                std::hint::black_box(&v);
            });
            println!("{}", meas.row());
            rows.push(meas);
        }
    }
    // Reference: std two-pointer merge of the same sizes.
    for outs in [16usize, 64, 256] {
        let m = outs / 2;
        let a = rng.sorted_list(m, 1 << 20);
        let b = rng.sorted_list(m, 1 << 20);
        let mut out = vec![0u32; outs];
        let meas = timing::bench(&format!("std two-pointer merge {outs}-out"), || {
            let (mut i, mut j, mut t) = (0, 0, 0);
            while i < a.len() && j < b.len() {
                if a[i] <= b[j] {
                    out[t] = a[i];
                    i += 1;
                } else {
                    out[t] = b[j];
                    j += 1;
                }
                t += 1;
            }
            out[t..t + a.len() - i].copy_from_slice(&a[i..]);
            let t2 = t + a.len() - i;
            out[t2..].copy_from_slice(&b[j..]);
            std::hint::black_box(&out);
        });
        println!("{}", meas.row());
    }
}
