//! Software-execution throughput of every device family — the host-side
//! analogue of the paper's device comparison, plus scaling over sizes.
//!
//! Every device is measured twice, side by side: the enum-tree
//! interpreter (`ExecScratch::run`) and the lowered IR
//! (`CompiledPlan::run_row`). A batch section then measures the four
//! executor variants on the software backend's serving shapes —
//! per-row interpreter loop, `CompiledPlan::run_batch`, the transposed
//! lane executor (`LanePlan::run_batch`), and lanes + multi-core
//! sharding (`lanes::run_batch_sharded`) — including the
//! `loms2_up32_dn32_b256` shape the default artifact set serves.

use loms::bench::timing;
use loms::sortnet::exec::{ExecMode, ExecScratch};
use loms::sortnet::lanes::{self, LanePlan, LaneScratch, LANES};
use loms::sortnet::plan::{CompiledPlan, PlanScratch};
use loms::sortnet::{batcher, loms as lm, s2ms};
use loms::util::Rng;

fn main() {
    let mut rng = Rng::new(9);
    // Smoke mode (`--smoke` / `BENCH_SMOKE=1`): fewer device sizes and
    // only the serving batch shape, with `timing::bench`'s reduced
    // budgets — every variant still executes once.
    let smoke = loms::bench::smoke_mode();
    let out_sizes: &[usize] = if smoke { &[16, 64] } else { &[16, 64, 256] };
    for &outs in out_sizes {
        let m = outs / 2;
        let devices = vec![
            (format!("batcher-oem {outs}-out"), batcher::odd_even_merge(m)),
            (format!("batcher-bitonic {outs}-out"), batcher::bitonic_merge(m)),
            (format!("s2ms {outs}-out"), s2ms::s2ms(m, m)),
            (format!("loms-2col {outs}-out"), lm::loms_2way(m, m, 2)),
            (format!("loms-8col {outs}-out"), lm::loms_2way(m, m, 8)),
        ];
        for (label, d) in devices {
            let a = rng.sorted_list(m, 1 << 20);
            let b = rng.sorted_list(m, 1 << 20);
            let mut v = d.load_inputs(&[a, b]);
            let base = v.clone();
            let mut scratch = ExecScratch::new();
            let interp = timing::bench(&format!("{label} [interp]"), || {
                v.copy_from_slice(&base);
                scratch.run(&d, &mut v, ExecMode::Fast, None).unwrap();
                std::hint::black_box(&v);
            });
            println!("{}", interp.row());
            let plan = CompiledPlan::compile(&d).expect("valid device");
            let mut ps = PlanScratch::new();
            let planned = timing::bench(&format!("{label} [plan]"), || {
                v.copy_from_slice(&base);
                plan.run_row(&mut v, ExecMode::Fast, None, &mut ps).unwrap();
                std::hint::black_box(&v);
            });
            println!("{}   ({:.2}x vs interp)", planned.row(), interp.mean_ns / planned.mean_ns);
        }
    }

    // The four executor variants on the software backend's serving
    // shapes. `loms2_up32_dn32_b256` is the default artifact set's batch
    // shape; the 4096-row shape shows where multi-core sharding pays
    // (thread spawn amortises only past ~tens of µs of work, which is
    // why `lanes::auto_threads` keeps small batches inline).
    let shapes: &[(usize, usize)] = if smoke { &[(32, 256)] } else { &[(32, 256), (32, 4096)] };
    for &(m, batch) in shapes {
        let d = lm::loms_2way(m, m, 2);
        let tag = format!("loms2_up{m}_dn{m}_b{batch}");
        let sizes = [m, m];
        let lists: Vec<Vec<u32>> = sizes
            .iter()
            .map(|&s| {
                let mut flat = Vec::with_capacity(batch * s);
                for _ in 0..batch {
                    flat.extend(rng.sorted_list(s, 1 << 20));
                }
                flat
            })
            .collect();
        let total = d.n;
        let mut out = Vec::with_capacity(batch * total);

        let mut scratch = ExecScratch::new();
        let mut v = vec![0u32; d.n];
        let per_row = timing::bench(&format!("{tag} [interp per-row]"), || {
            out.clear();
            for row in 0..batch {
                for (l, &s) in sizes.iter().enumerate() {
                    let slice = &lists[l][row * s..(row + 1) * s];
                    for (i, &x) in slice.iter().enumerate() {
                        v[d.input_map[l][i]] = x;
                    }
                }
                scratch.run(&d, &mut v, ExecMode::Fast, None).unwrap();
                out.extend(d.output_perm.iter().map(|&p| v[p]));
            }
            std::hint::black_box(&out);
        });
        println!("{}", per_row.row());

        let plan = CompiledPlan::compile_auto(&d).expect("valid device");
        let mut ps = PlanScratch::new();
        let batched = timing::bench(&format!("{tag} [plan run_batch]"), || {
            out.clear();
            plan.run_batch(&lists, batch, ExecMode::Fast, &mut ps, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        println!("{}   ({:.2}x vs interp)", batched.row(), per_row.mean_ns / batched.mean_ns);

        let lane = LanePlan::compile(&plan);
        let mut ls = LaneScratch::new();
        let laned = timing::bench(&format!("{tag} [lanes x{LANES}]"), || {
            out.clear();
            lane.run_batch(&plan, &lists, batch, &mut ls, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        println!("{}   ({:.2}x vs interp)", laned.row(), per_row.mean_ns / laned.mean_ns);

        let threads = lanes::forced_threads(batch);
        let sharded = timing::bench(&format!("{tag} [lanes+{threads}thr]"), || {
            out.clear();
            lanes::run_batch_sharded(&lane, &plan, &lists, batch, threads, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        println!("{}   ({:.2}x vs interp)", sharded.row(), per_row.mean_ns / sharded.mean_ns);

        // Tile-direct view path — the serving executor's call shape
        // (ragged per-request views in, per-row response buffers out).
        // Exact-shape rows here, so any delta vs [lanes] is pure data
        // path: scatter-from-views + per-row gather instead of flat
        // row-major input and a whole-batch output vector.
        let reqs: Vec<Vec<Vec<u32>>> = (0..batch)
            .map(|row| {
                sizes
                    .iter()
                    .enumerate()
                    .map(|(l, &s)| lists[l][row * s..(row + 1) * s].to_vec())
                    .collect()
            })
            .collect();
        let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
        let mut merged: Vec<Vec<u32>> = (0..batch).map(|_| vec![0u32; total]).collect();
        let viewed = timing::bench(&format!("{tag} [lanes view-direct]"), || {
            let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
            lane.run_view_batch_into(&plan, &rows, u32::MAX, &mut ls, &mut outs).unwrap();
            std::hint::black_box(&merged);
        });
        println!("{}   ({:.2}x vs interp)", viewed.row(), per_row.mean_ns / viewed.mean_ns);
        println!(
            "{tag}: plan {:.2}x | lanes {:.2}x | lanes+{}thr {:.2}x vs per-row interpreter \
             ({} CAS + {} copy steps/tile, {} slots, pruned={}, auto_threads would use {})",
            per_row.mean_ns / batched.mean_ns,
            per_row.mean_ns / laned.mean_ns,
            threads,
            per_row.mean_ns / sharded.mean_ns,
            lane.cas_count(),
            lane.copy_count(),
            lane.slots(),
            plan.is_pruned(),
            lanes::auto_threads(batch, plan.n()),
        );
    }

    // Reference: std two-pointer merge of the same sizes.
    for &outs in out_sizes {
        let m = outs / 2;
        let a = rng.sorted_list(m, 1 << 20);
        let b = rng.sorted_list(m, 1 << 20);
        let mut out = vec![0u32; outs];
        let meas = timing::bench(&format!("std two-pointer merge {outs}-out"), || {
            let (mut i, mut j, mut t) = (0, 0, 0);
            while i < a.len() && j < b.len() {
                if a[i] <= b[j] {
                    out[t] = a[i];
                    i += 1;
                } else {
                    out[t] = b[j];
                    j += 1;
                }
                t += 1;
            }
            out[t..t + a.len() - i].copy_from_slice(&a[i..]);
            let t2 = t + a.len() - i;
            out[t2..].copy_from_slice(&b[j..]);
            std::hint::black_box(&out);
        });
        println!("{}", meas.row());
    }
}
