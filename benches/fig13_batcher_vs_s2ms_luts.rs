//! Regenerates Fig. 13 (32-bit LUT usage: OEMS/Bitonic/S2MS on both
//! FPGAs) from the cost model.

use loms::bench::figures;

fn main() {
    let f = figures::fig13();
    println!("{}", f.to_table());
    let p = f.save_csv("bench_out").expect("csv");
    println!("   csv → {}", p.display());
}
