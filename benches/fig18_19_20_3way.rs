//! Regenerates Figs. 18, 19 & 20 (3c_7r 3-way median/full delays and
//! LUTs: LOMS vs the MWMS baseline) and times software execution of the
//! two 3-way devices.

use loms::bench::{figures, timing};
use loms::sortnet::exec::{ExecMode, ExecScratch};
use loms::sortnet::{loms as lm, mwms};
use loms::util::Rng;

fn main() {
    for f in [figures::fig18(), figures::fig19(), figures::fig20()] {
        println!("{}", f.to_table());
        let p = f.save_csv("bench_out").expect("csv");
        println!("   csv → {}\n", p.display());
    }
    println!("{}", figures::mwms_note());
    let mut rng = Rng::new(3);
    for (label, d) in [
        ("loms 3c_7r software exec", lm::loms_kway(&[7, 7, 7])),
        ("mwms 3c_7r software exec", mwms::mwms_3way(7)),
    ] {
        let lists: Vec<Vec<u32>> = (0..3).map(|_| rng.sorted_list(7, 1 << 20)).collect();
        let mut v = d.load_inputs(&lists);
        let base = v.clone();
        let mut scratch = ExecScratch::new();
        let meas = timing::bench(label, || {
            v.copy_from_slice(&base);
            scratch.run(&d, &mut v, ExecMode::Fast, None).unwrap();
            std::hint::black_box(&v);
        });
        println!("{}", meas.row());
    }
}
