//! Streaming merge engine throughput, emitted as `BENCH_stream.json`.
//!
//! Three engines over the same workloads (keys/s, higher is better):
//!
//! 1. `heap_kway` — [`planner::kway_merge`], the scalar binary heap
//!    that used to finish every external sort (log₂k branchy compares
//!    per key).
//! 2. `tile_kway` — [`stream::merge_runs`], the FLiMS-style merge tree
//!    pumping R+R LOMS kernels: independent tree nodes batch into
//!    transposed SIMD tiles, so per-key work is branchless CAS chains.
//! 3. `extsort` — `stream::extsort` end to end (run formation +
//!    streaming k-way) on unsorted input, the bounded-memory path
//!    behind `loms sort`.
//!
//! The k-way engines run at k ∈ {4, 16, 64} over ≥1M-key workloads by
//! default (`BENCH_KEYS` overrides). CI compile-checks this harness via
//! `cargo bench --no-run`; run `cargo bench --bench stream_throughput`
//! to refresh the JSON.

use loms::coordinator::planner;
use loms::stream::{self, ExtSortConfig};
use loms::util::Rng;
use std::time::Instant;

struct Variant {
    name: &'static str,
    k: usize,
    keys_per_s: f64,
}

/// Best keys/s over a warmup + 3 timed repetitions (same spirit as
/// `bench::timing`, but each op here is huge). `prep` runs off the
/// clock — the heap variant clones its consumable input there.
fn best_rate<T>(keys: usize, mut prep: impl FnMut() -> T, mut run: impl FnMut(T) -> usize) -> f64 {
    run(prep()); // warmup
    let mut best = f64::MIN;
    for _ in 0..3 {
        let input = prep();
        let t0 = Instant::now();
        let produced = run(input);
        assert_eq!(produced, keys);
        best = best.max(keys as f64 / t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let n: usize = std::env::var("BENCH_KEYS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 20);
    let r = stream::DEFAULT_R;
    let mut rng = Rng::new(0x57B3);
    let mut variants: Vec<Variant> = Vec::new();

    for k in [4usize, 16, 64] {
        // k pre-sorted runs of ~n/k keys (ragged by one).
        let runs: Vec<Vec<u32>> = (0..k)
            .map(|i| rng.sorted_list(n / k + (i % 2), u32::MAX - 1))
            .collect();
        let total: usize = runs.iter().map(Vec::len).sum();

        let heap = best_rate(total, || runs.clone(), |input| planner::kway_merge(input).len());
        variants.push(Variant { name: "heap_kway", k, keys_per_s: heap });

        let tile = best_rate(total, || (), |()| stream::merge_runs(&runs, r).unwrap().len());
        variants.push(Variant { name: "tile_kway", k, keys_per_s: tile });

        println!(
            "k={k:<3} heap {heap:>12.0} keys/s   tile {tile:>12.0} keys/s   ({:.2}x)",
            tile / heap
        );
    }

    // End-to-end external sort of unsorted input (in-memory runs).
    let data: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
    let cfg = ExtSortConfig { r, ..Default::default() };
    let ext = best_rate(n, || (), |()| stream::extsort(&data, &cfg).unwrap().0.len());
    let ext_runs = n.div_ceil(cfg.run_len);
    variants.push(Variant { name: "extsort", k: ext_runs, keys_per_s: ext });
    println!("extsort (runs={ext_runs}) {ext:>12.0} keys/s");

    let rows: Vec<String> = variants
        .iter()
        .map(|v| {
            format!(
                "    {{\"name\": \"{}\", \"k\": {}, \"keys_per_s\": {:.0}}}",
                v.name, v.k, v.keys_per_s
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"stream_throughput\",\n  \"keys\": {n},\n  \"r\": {r},\n  \
         \"variants\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_stream.json", &json).expect("write BENCH_stream.json");
    println!("wrote BENCH_stream.json");
}
