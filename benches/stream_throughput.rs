//! Streaming merge engine throughput, emitted as `BENCH_stream.json`.
//!
//! Key-only and key-value engines over the same workloads (keys/s,
//! higher is better):
//!
//! 1. `heap_kway` — [`planner::kway_merge`], the scalar binary heap
//!    that used to finish every external sort (log₂k branchy compares
//!    per key).
//! 2. `tile_kway` — [`stream::merge_runs`], the FLiMS-style merge tree
//!    pumping R+R LOMS kernels: independent tree nodes batch into
//!    transposed SIMD tiles, so per-key work is branchless CAS chains.
//! 3. `tile_kway_kv` — [`stream::merge_runs_kv`], the same tree on the
//!    rank-then-permute lowering: keys packed with origin ranks run the
//!    u64 CAS stream, one `u64` payload per key moves exactly once per
//!    node step through the emitted permutation. The delta to
//!    `tile_kway` is the price of carrying payloads.
//! 4. `extsort` / `extsort_kv` — the end-to-end external sorts on
//!    unsorted input, the bounded-memory paths behind `loms sort`
//!    (`--payload true` for the KV row).
//!
//! The k-way engines run at k ∈ {4, 16, 64} over ≥1M-key workloads by
//! default (`BENCH_KEYS` overrides; `--smoke` / `BENCH_SMOKE=1` drops
//! to 2^16 keys for CI). CI runs this harness in smoke mode and
//! uploads the JSON; run `cargo bench --bench stream_throughput` for
//! full-size numbers.

use loms::coordinator::planner;
use loms::stream::{self, ExtSortConfig};
use loms::util::Rng;
use std::time::Instant;

struct Variant {
    name: &'static str,
    mode: &'static str,
    k: usize,
    keys_per_s: f64,
}

/// Best keys/s over a warmup + 3 timed repetitions (same spirit as
/// `bench::timing`, but each op here is huge). `prep` runs off the
/// clock — the heap variant clones its consumable input there.
fn best_rate<T>(keys: usize, mut prep: impl FnMut() -> T, mut run: impl FnMut(T) -> usize) -> f64 {
    run(prep()); // warmup
    let mut best = f64::MIN;
    for _ in 0..3 {
        let input = prep();
        let t0 = Instant::now();
        let produced = run(input);
        assert_eq!(produced, keys);
        best = best.max(keys as f64 / t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let n: usize = std::env::var("BENCH_KEYS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if loms::bench::smoke_mode() { 1 << 16 } else { 1 << 20 });
    let r = stream::DEFAULT_R;
    let mut rng = Rng::new(0x57B3);
    let mut variants: Vec<Variant> = Vec::new();

    for k in [4usize, 16, 64] {
        // k pre-sorted runs of ~n/k keys (ragged by one).
        let runs: Vec<Vec<u32>> = (0..k)
            .map(|i| rng.sorted_list(n / k + (i % 2), u32::MAX - 1))
            .collect();
        let total: usize = runs.iter().map(Vec::len).sum();
        // The same runs with a payload column per key (tags unique
        // across the whole workload).
        let kv_runs: Vec<(Vec<u32>, Vec<u64>)> = runs
            .iter()
            .enumerate()
            .map(|(i, keys)| {
                let pays = (0..keys.len() as u64).map(|t| ((i as u64) << 32) | t).collect();
                (keys.clone(), pays)
            })
            .collect();

        let heap = best_rate(total, || runs.clone(), |input| planner::kway_merge(input).len());
        variants.push(Variant { name: "heap_kway", mode: "key_only", k, keys_per_s: heap });

        let tile = best_rate(total, || (), |()| stream::merge_runs(&runs, r).unwrap().len());
        variants.push(Variant { name: "tile_kway", mode: "key_only", k, keys_per_s: tile });

        let tile_kv = best_rate(total, || (), |()| {
            let (keys, pays) = stream::merge_runs_kv(&kv_runs, r).unwrap();
            assert_eq!(pays.len(), keys.len());
            keys.len()
        });
        variants.push(Variant { name: "tile_kway_kv", mode: "key_value", k, keys_per_s: tile_kv });

        println!(
            "k={k:<3} heap {heap:>12.0} keys/s   tile {tile:>12.0} keys/s ({:.2}x)   \
             tile-kv {tile_kv:>12.0} keys/s ({:.2}x of tile)",
            tile / heap,
            tile_kv / tile
        );
    }

    // End-to-end external sorts of unsorted input (in-memory runs).
    let data: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
    let pays: Vec<u64> = (0..n as u64).collect();
    let cfg = ExtSortConfig { r, ..Default::default() };
    let ext = best_rate(n, || (), |()| stream::extsort(&data, &cfg).unwrap().0.len());
    let ext_runs = n.div_ceil(cfg.run_len);
    variants.push(Variant { name: "extsort", mode: "key_only", k: ext_runs, keys_per_s: ext });
    let ext_kv = best_rate(n, || (), |()| {
        let (keys, sorted_pays, _) = stream::extsort_kv(&data, &pays, &cfg).unwrap();
        assert_eq!(sorted_pays.len(), keys.len());
        keys.len()
    });
    variants.push(Variant {
        name: "extsort_kv",
        mode: "key_value",
        k: ext_runs,
        keys_per_s: ext_kv,
    });
    println!(
        "extsort (runs={ext_runs}) {ext:>12.0} keys/s   extsort-kv {ext_kv:>12.0} keys/s \
         ({:.2}x of key-only)",
        ext_kv / ext
    );

    let rows: Vec<String> = variants
        .iter()
        .map(|v| {
            format!(
                "    {{\"name\": \"{}\", \"mode\": \"{}\", \"k\": {}, \"keys_per_s\": {:.0}}}",
                v.name, v.mode, v.k, v.keys_per_s
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"stream_throughput\",\n  \"keys\": {n},\n  \"r\": {r},\n  \
         \"simd_tier\": \"{:?}\",\n  \"variants\": [\n{}\n  ]\n}}\n",
        loms::sortnet::lanes::active_tier(),
        rows.join(",\n")
    );
    std::fs::write("BENCH_stream.json", &json).expect("write BENCH_stream.json");
    println!("wrote BENCH_stream.json");
}
