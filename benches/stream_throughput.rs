//! Streaming merge engine throughput, emitted as `BENCH_stream.json`.
//!
//! Key-only and key-value engines over the same workloads (keys/s,
//! higher is better):
//!
//! 1. `heap_kway` — [`planner::kway_merge`], the scalar binary heap
//!    that used to finish every external sort (log₂k branchy compares
//!    per key).
//! 2. `tile_kway` — [`stream::merge_runs`], the FLiMS-style merge tree
//!    pumping R+R LOMS kernels: independent tree nodes batch into
//!    transposed SIMD tiles, so per-key work is branchless CAS chains.
//! 3. `tile_kway_kv` — [`stream::merge_runs_kv`], the same tree on the
//!    rank-then-permute lowering: keys packed with origin ranks run the
//!    u64 CAS stream, one `u64` payload per key moves exactly once per
//!    node step through the emitted permutation. The delta to
//!    `tile_kway` is the price of carrying payloads.
//! 4. `extsort` / `extsort_kv` — the end-to-end external sorts on
//!    unsorted input, the bounded-memory paths behind `loms sort`
//!    (`--payload true` for the KV row).
//! 5. `encode_*` — the bulk LE spill codecs against the naive per-key
//!    loop they replaced, as a regression guard (the bulk path must
//!    stay within 2x of naive even on pessimal allocators; in practice
//!    it's the faster one).
//! 6. `extsort_e2e` — disk-to-disk external sorts (`extsort_file` /
//!    `extsort_kv_file`) over a (sort_threads, partitions) matrix,
//!    reported as `extsort_e2e_bytes_per_sec` (input bytes through the
//!    full read → sort → spill → merge → write pipeline), plus one
//!    checksum-on vs checksum-off pair guarding the CRC-32 spill
//!    sidecar overhead (≤5% full-size, ≤25% in noisy smoke mode).
//!
//! The k-way engines run at k ∈ {4, 16, 64} over ≥1M-key workloads by
//! default (`BENCH_KEYS` overrides; `--smoke` / `BENCH_SMOKE=1` drops
//! to 2^16 keys for CI). CI runs this harness in smoke mode and
//! uploads the JSON; run `cargo bench --bench stream_throughput` for
//! full-size numbers.

use loms::coordinator::planner;
use loms::stream::{self, encode_keys_into, encode_records_into, ExtSortConfig};
use loms::util::Rng;
use std::path::Path;
use std::time::Instant;

struct Variant {
    name: &'static str,
    mode: &'static str,
    k: usize,
    keys_per_s: f64,
}

/// Best keys/s over a warmup + 3 timed repetitions (same spirit as
/// `bench::timing`, but each op here is huge). `prep` runs off the
/// clock — the heap variant clones its consumable input there.
fn best_rate<T>(keys: usize, mut prep: impl FnMut() -> T, mut run: impl FnMut(T) -> usize) -> f64 {
    run(prep()); // warmup
    let mut best = f64::MIN;
    for _ in 0..3 {
        let input = prep();
        let t0 = Instant::now();
        let produced = run(input);
        assert_eq!(produced, keys);
        best = best.max(keys as f64 / t0.elapsed().as_secs_f64());
    }
    best
}

/// Disk-to-disk rate for one matrix cell: warmup + best of 2 timed
/// runs, input bytes over wall time for the whole pipeline.
fn e2e_rate(
    input: &Path,
    output: &Path,
    cfg: &ExtSortConfig,
    bytes: usize,
    keys: usize,
    kv: bool,
) -> f64 {
    let mut best = f64::MIN;
    for rep in 0..3 {
        let t0 = Instant::now();
        let stats = if kv {
            stream::extsort_kv_file(input, output, cfg).unwrap()
        } else {
            stream::extsort_file(input, output, cfg).unwrap()
        };
        assert_eq!(stats.keys, keys);
        if rep > 0 {
            best = best.max(bytes as f64 / t0.elapsed().as_secs_f64());
        }
    }
    best
}

/// The `extsort_e2e` matrix: key-only and KV file sorts at three
/// (sort_threads, partitions) settings (serial baseline, explicit 2×2,
/// auto). Returns pre-formatted JSON rows.
fn bench_e2e(data: &[u32], pays: &[u64]) -> Vec<String> {
    let n = data.len();
    let dir = std::env::temp_dir().join(format!("loms_bench_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let key_in = dir.join("keys.u32");
    let kv_in = dir.join("pairs.kv12");
    let mut bytes = Vec::new();
    encode_keys_into(data, &mut bytes);
    std::fs::write(&key_in, &bytes).unwrap();
    encode_records_into(data, pays, &mut bytes);
    std::fs::write(&kv_in, &bytes).unwrap();
    // ~8 phase-1 runs so the matrix exercises a real merge even at
    // smoke scale; fan-in 4 forces one intermediate (rolling) pass.
    let base = ExtSortConfig {
        run_len: (n / 8).max(1024),
        max_fanin: 4,
        spill_dir: Some(dir.clone()),
        ..Default::default()
    };
    let mut rows = Vec::new();
    for (sort_threads, partitions) in [(1usize, 1usize), (2, 2), (0, 0)] {
        let cfg = ExtSortConfig { sort_threads, partitions, ..base.clone() };
        for (mode, input, kv) in [("key_only", &key_in, false), ("key_value", &kv_in, true)] {
            let in_bytes = std::fs::metadata(input).unwrap().len() as usize;
            let out = dir.join("out.tmp");
            let rate = e2e_rate(input, &out, &cfg, in_bytes, n, kv);
            println!(
                "extsort-e2e {mode:<9} threads={sort_threads} parts={partitions} \
                 {rate:>12.0} bytes/s"
            );
            rows.push(format!(
                "    {{\"mode\": \"{mode}\", \"sort_threads\": {sort_threads}, \
                 \"partitions\": {partitions}, \"extsort_e2e_bytes_per_sec\": {rate:.0}}}"
            ));
        }
    }
    // Spill-integrity guard: the per-block CRC-32 sidecars (on by
    // default) vs `verify_spill: false`, same cell of the matrix. The
    // slicing-by-8 CRC runs at memory-bandwidth-adjacent rates, so the
    // checksummed pipeline must stay within 5% of the raw one; smoke
    // mode only sanity-checks at 25% because 2^16-key runs are noise-
    // dominated on shared CI machines.
    let in_bytes = std::fs::metadata(&key_in).unwrap().len() as usize;
    let out = dir.join("out.tmp");
    let cfg_on = ExtSortConfig { sort_threads: 2, partitions: 2, ..base.clone() };
    let cfg_off = ExtSortConfig { verify_spill: false, ..cfg_on.clone() };
    let rate_on = e2e_rate(&key_in, &out, &cfg_on, in_bytes, n, false);
    let rate_off = e2e_rate(&key_in, &out, &cfg_off, in_bytes, n, false);
    let floor = if loms::bench::smoke_mode() { 0.75 } else { 0.95 };
    println!(
        "extsort-e2e checksum on {rate_on:>12.0} bytes/s   off {rate_off:>12.0} bytes/s \
         ({:.3}x, floor {floor})",
        rate_on / rate_off
    );
    assert!(
        rate_on >= floor * rate_off,
        "spill checksum overhead too high: {rate_on:.0} vs {rate_off:.0} bytes/s \
         ({:.1}% slower, allowed {:.0}%)",
        100.0 * (1.0 - rate_on / rate_off),
        100.0 * (1.0 - floor)
    );
    for (checksum, rate) in [("on", rate_on), ("off", rate_off)] {
        rows.push(format!(
            "    {{\"mode\": \"key_only\", \"sort_threads\": 2, \"partitions\": 2, \
             \"checksum\": \"{checksum}\", \"extsort_e2e_bytes_per_sec\": {rate:.0}}}"
        ));
    }
    let _ = std::fs::remove_dir_all(&dir);
    rows
}

fn main() {
    let n: usize = std::env::var("BENCH_KEYS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if loms::bench::smoke_mode() { 1 << 16 } else { 1 << 20 });
    let r = stream::DEFAULT_R;
    let mut rng = Rng::new(0x57B3);
    let mut variants: Vec<Variant> = Vec::new();

    for k in [4usize, 16, 64] {
        // k pre-sorted runs of ~n/k keys (ragged by one).
        let runs: Vec<Vec<u32>> = (0..k)
            .map(|i| rng.sorted_list(n / k + (i % 2), u32::MAX - 1))
            .collect();
        let total: usize = runs.iter().map(Vec::len).sum();
        // The same runs with a payload column per key (tags unique
        // across the whole workload).
        let kv_runs: Vec<(Vec<u32>, Vec<u64>)> = runs
            .iter()
            .enumerate()
            .map(|(i, keys)| {
                let pays = (0..keys.len() as u64).map(|t| ((i as u64) << 32) | t).collect();
                (keys.clone(), pays)
            })
            .collect();

        let heap = best_rate(total, || runs.clone(), |input| planner::kway_merge(input).len());
        variants.push(Variant { name: "heap_kway", mode: "key_only", k, keys_per_s: heap });

        let tile = best_rate(total, || (), |()| stream::merge_runs(&runs, r).unwrap().len());
        variants.push(Variant { name: "tile_kway", mode: "key_only", k, keys_per_s: tile });

        let tile_kv = best_rate(total, || (), |()| {
            let (keys, pays) = stream::merge_runs_kv(&kv_runs, r).unwrap();
            assert_eq!(pays.len(), keys.len());
            keys.len()
        });
        variants.push(Variant { name: "tile_kway_kv", mode: "key_value", k, keys_per_s: tile_kv });

        println!(
            "k={k:<3} heap {heap:>12.0} keys/s   tile {tile:>12.0} keys/s ({:.2}x)   \
             tile-kv {tile_kv:>12.0} keys/s ({:.2}x of tile)",
            tile / heap,
            tile_kv / tile
        );
    }

    // End-to-end external sorts of unsorted input (in-memory runs).
    let data: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
    let pays: Vec<u64> = (0..n as u64).collect();
    let cfg = ExtSortConfig { r, ..Default::default() };
    let ext = best_rate(n, || (), |()| stream::extsort(&data, &cfg).unwrap().0.len());
    let ext_runs = n.div_ceil(cfg.run_len);
    variants.push(Variant { name: "extsort", mode: "key_only", k: ext_runs, keys_per_s: ext });
    let ext_kv = best_rate(n, || (), |()| {
        let (keys, sorted_pays, _) = stream::extsort_kv(&data, &pays, &cfg).unwrap();
        assert_eq!(sorted_pays.len(), keys.len());
        keys.len()
    });
    variants.push(Variant {
        name: "extsort_kv",
        mode: "key_value",
        k: ext_runs,
        keys_per_s: ext_kv,
    });
    println!(
        "extsort (runs={ext_runs}) {ext:>12.0} keys/s   extsort-kv {ext_kv:>12.0} keys/s \
         ({:.2}x of key-only)",
        ext_kv / ext
    );

    // Spill-codec guard: the bulk LE encoders vs the per-key loop they
    // replaced. A loose floor (bulk ≥ 0.5× naive) catches accidental
    // regressions to quadratic or per-key-allocating behavior without
    // flaking on noisy CI machines.
    let naive_keys = best_rate(n, Vec::new, |mut out: Vec<u8>| {
        for &k in &data {
            out.extend_from_slice(&k.to_le_bytes());
        }
        out.len() / 4
    });
    let bulk_keys = best_rate(n, Vec::new, |mut out: Vec<u8>| {
        encode_keys_into(&data, &mut out);
        out.len() / 4
    });
    let naive_recs = best_rate(n, Vec::new, |mut out: Vec<u8>| {
        for (&k, &p) in data.iter().zip(&pays) {
            out.extend_from_slice(&k.to_le_bytes());
            out.extend_from_slice(&p.to_le_bytes());
        }
        out.len() / 12
    });
    let bulk_recs = best_rate(n, Vec::new, |mut out: Vec<u8>| {
        encode_records_into(&data, &pays, &mut out);
        out.len() / 12
    });
    assert!(bulk_keys >= 0.5 * naive_keys, "bulk key encode regressed: {bulk_keys} vs {naive_keys}");
    assert!(bulk_recs >= 0.5 * naive_recs, "bulk record encode regressed: {bulk_recs} vs {naive_recs}");
    for (name, rate) in [
        ("encode_keys_naive", naive_keys),
        ("encode_keys_bulk", bulk_keys),
        ("encode_records_naive", naive_recs),
        ("encode_records_bulk", bulk_recs),
    ] {
        variants.push(Variant { name, mode: "codec", k: 1, keys_per_s: rate });
    }
    println!(
        "encode keys {bulk_keys:>12.0}/s ({:.2}x of naive)   records {bulk_recs:>12.0}/s \
         ({:.2}x of naive)",
        bulk_keys / naive_keys,
        bulk_recs / naive_recs
    );

    // Disk-to-disk external sorts over a (sort_threads, partitions)
    // matrix: the full read → parallel run formation → spill → rolling
    // merge passes → range-partitioned final merge → write pipeline.
    let e2e_rows = bench_e2e(&data, &pays);

    let rows: Vec<String> = variants
        .iter()
        .map(|v| {
            format!(
                "    {{\"name\": \"{}\", \"mode\": \"{}\", \"k\": {}, \"keys_per_s\": {:.0}}}",
                v.name, v.mode, v.k, v.keys_per_s
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"stream_throughput\",\n  \"keys\": {n},\n  \"r\": {r},\n  \
         \"simd_tier\": \"{:?}\",\n  \"variants\": [\n{}\n  ],\n  \
         \"extsort_e2e\": [\n{}\n  ]\n}}\n",
        loms::sortnet::lanes::active_tier(),
        rows.join(",\n"),
        e2e_rows.join(",\n")
    );
    std::fs::write("BENCH_stream.json", &json).expect("write BENCH_stream.json");
    println!("wrote BENCH_stream.json");
}
