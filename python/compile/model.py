"""Layer 2: the batched merge computation graphs (variant registry).

Each variant pairs a netgen device with a kernel lowering mode and a
batch shape; ``aot.py`` lowers every variant once to HLO text for the
Rust runtime, and the pytest suite checks each against the pure-jnp
oracle. Python never runs at request time — these functions exist only
on the compile path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from .kernels.pallas_kernel import make_pallas_merge, vmem_bytes
from .kernels.plan import lower, plan_stats
from .netgen import batcher, loms, s2ms
from .netgen.device import MergeDevice


@dataclass(frozen=True)
class Variant:
    """One AOT-compiled merge executable."""

    name: str
    device_fn: Callable[[], MergeDevice]
    mode: str  # "rank" (LOMS/S2MS style) | "cas" (Batcher style)
    batch: int
    block_b: int

    def device(self) -> MergeDevice:
        return self.device_fn()

    def build(self):
        """The jit-able batched merge fn (Pallas kernel inside)."""
        return make_pallas_merge(self.device(), self.batch, self.mode, self.block_b)

    def input_shapes(self) -> list[tuple[int, int]]:
        return [(self.batch, s) for s in self.device().list_sizes]

    def meta(self) -> dict:
        d = self.device()
        stats = plan_stats(lower(d, self.mode))
        return {
            "name": self.name,
            "device": d.name,
            "mode": self.mode,
            "batch": self.batch,
            "block_b": self.block_b,
            "list_sizes": d.list_sizes,
            "total": d.n,
            "dtype": "u32",
            "hw_stages": d.depth(),
            "plan_steps": stats["steps"],
            "vmem_bytes_per_block": vmem_bytes(d, min(self.block_b, self.batch)),
        }


# The merge ladder the coordinator serves (powers of two for the external
# sort), the paper's flagship 2-way devices, the Batcher/S2MS baselines,
# and the 3-way device.
VARIANTS: dict[str, Variant] = {
    v.name: v
    for v in [
        # Batch/block shapes picked by the §Perf scan (EXPERIMENTS.md):
        # throughput-optimal on the CPU PJRT backend at acceptable
        # batching latency.
        Variant("loms2_up32_dn32_b256", lambda: loms.loms_2way(32, 32, 2), "rank", 256, 128),
        Variant("loms2_up64_dn64_b128", lambda: loms.loms_2way(64, 64, 2), "rank", 128, 64),
        Variant("loms2_up128_dn128_b16", lambda: loms.loms_2way(128, 128, 4), "rank", 16, 8),
        Variant("loms2_up256_dn256_b32", lambda: loms.loms_2way(256, 256, 8), "rank", 32, 16),
        Variant("batcher_up32_dn32_b64", lambda: batcher.odd_even_merge(32), "cas", 64, 32),
        Variant("s2ms_up32_dn32_b64", lambda: s2ms.s2ms(32, 32), "rank", 64, 32),
        Variant("loms3_7r_b256", lambda: loms.loms_kway([7, 7, 7]), "rank", 256, 128),
    ]
}


def example_args(v: Variant) -> list[jnp.ndarray]:
    """Deterministic example inputs (sorted ascending rows)."""
    out = []
    for li, (b, s) in enumerate(v.input_shapes()):
        base = jnp.arange(b, dtype=jnp.uint32)[:, None] * 131 + li * 17
        row = jnp.arange(s, dtype=jnp.uint32)[None, :] * 3
        out.append(base + row)
    return out
