"""AOT compilation: lower every model variant to HLO **text** and write
the artifact manifest.

HLO text (not ``serialize()``d HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the published
``xla`` crate's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md). Lowering
uses ``return_tuple=True``, so the Rust side unwraps with ``to_tuple1``.

Usage: ``python -m compile.aot --out ../artifacts`` (idempotent; the
Makefile `artifacts` target skips it when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import VARIANTS, Variant


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default HLO printer
    # ELIDES wide literals ("constant({...})"), and the xla_extension
    # 0.5.1 text parser fills the gap with zeros — every static
    # index/mask array of the merge plans would silently become zeros
    # (observed: merges returning the per-row maximum everywhere).
    text = comp.as_hlo_text(True)
    assert "..." not in text, "HLO text still contains elided constants"
    return text


def lower_variant(v: Variant) -> str:
    fn = v.build()
    specs = [jax.ShapeDtypeStruct(shape, jax.numpy.uint32) for shape in v.input_shapes()]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", nargs="*", help="subset of variant names")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    names = args.only or list(VARIANTS)
    manifest = []
    for name in names:
        v = VARIANTS[name]
        text = lower_variant(v)
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        meta = v.meta()
        meta["file"] = path.name
        manifest.append(meta)
        print(f"wrote {path} ({len(text)} chars, plan_steps={meta['plan_steps']})")
    (out / "manifest.json").write_text(json.dumps({"artifacts": manifest}, indent=2, sort_keys=True))
    print(f"wrote {out/'manifest.json'} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
