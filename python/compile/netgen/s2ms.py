"""Single-Stage 2-way Merge Sorter device (Python mirror of
``rust/src/sortnet/s2ms.rs``)."""

from __future__ import annotations

from .device import MergeDevice, MergeS2, Stage


def s2ms(m: int, n: int) -> MergeDevice:
    """UP-m/DN-n single-stage merge: one MergeS2 block."""
    total = m + n
    return MergeDevice(
        name=f"s2ms-up{m}-dn{n}",
        kind="s2ms",
        list_sizes=[m, n],
        input_map=[list(range(m)), list(range(m, total))],
        n=total,
        stages=[Stage("s2ms", [MergeS2(tuple(range(m)), tuple(range(m, total)), tuple(range(total)))])],
        output_perm=list(range(total)),
    )
