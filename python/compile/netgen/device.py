"""Merge-device representation — the compile-time (Python) mirror of
``rust/src/sortnet/network.rs``.

The Rust crate is the runtime implementation; this module exists so the
JAX/Pallas kernels can be *constructed* at AOT time without invoking the
Rust toolchain. The two implementations are independently written and
cross-checked structurally through golden JSON vectors
(``tests/golden/*.json``, emitted by ``loms netgen --golden``).

Conventions match the Rust side exactly: values ascend, ``input_map[l][i]``
is the flat position of list ``l``'s i-th smallest value, flat positions
are assigned in output-scan order (``output_perm`` is the identity for
LOMS devices), and block semantics are "sorted ascending into listed
positions".
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Cas:
    """2-sorter: after execution value at ``lo`` <= value at ``hi``."""

    lo: int
    hi: int

    def reads(self) -> list[int]:
        return [self.lo, self.hi]

    def to_json(self) -> dict:
        return {"type": "cas", "lo": self.lo, "hi": self.hi}


@dataclass(frozen=True)
class SortN:
    """Single-stage N-sorter: sorts ``pos`` ascending into listed order."""

    pos: tuple[int, ...]

    def reads(self) -> list[int]:
        return list(self.pos)

    def to_json(self) -> dict:
        return {"type": "sortN", "pos": list(self.pos)}


@dataclass(frozen=True)
class MergeS2:
    """S2MS block: merges sorted runs ``up`` and ``dn``; merged rank t
    lands at ``out[t]``."""

    up: tuple[int, ...]
    dn: tuple[int, ...]
    out: tuple[int, ...]

    def reads(self) -> list[int]:
        return list(self.up) + list(self.dn)

    def to_json(self) -> dict:
        return {"type": "s2ms", "up": list(self.up), "dn": list(self.dn), "out": list(self.out)}


@dataclass(frozen=True)
class FilterN:
    """N-filter: writes only the tapped ranks of the sorted ``pos``."""

    pos: tuple[int, ...]
    taps: tuple[int, ...]

    def reads(self) -> list[int]:
        return list(self.pos)

    def to_json(self) -> dict:
        return {"type": "filterN", "pos": list(self.pos), "taps": list(self.taps)}


Block = Cas | SortN | MergeS2 | FilterN


@dataclass
class Stage:
    label: str
    blocks: list[Block] = field(default_factory=list)

    def to_json(self) -> dict:
        return {"label": self.label, "blocks": [b.to_json() for b in self.blocks]}


@dataclass
class MergeDevice:
    name: str
    kind: str
    list_sizes: list[int]
    input_map: list[list[int]]
    n: int
    stages: list[Stage]
    output_perm: list[int]
    median_tap: tuple[int, int] | None = None
    grid: tuple[int, int] | None = None

    def check(self) -> None:
        assert sum(self.list_sizes) == self.n, f"{self.name}: size sum"
        seen = [False] * self.n
        for l, m in enumerate(self.input_map):
            assert len(m) == self.list_sizes[l], f"{self.name}: input_map[{l}] len"
            for p in m:
                assert 0 <= p < self.n and not seen[p], f"{self.name}: input_map pos {p}"
                seen[p] = True
        assert all(seen), f"{self.name}: input_map incomplete"
        assert sorted(self.output_perm) == list(range(self.n)), f"{self.name}: output_perm"
        for si, stage in enumerate(self.stages):
            touched = [False] * self.n
            for b in stage.blocks:
                if isinstance(b, MergeS2):
                    assert sorted(b.out) == sorted(b.reads()), f"{self.name}: s2ms out perm"
                for p in b.reads():
                    assert 0 <= p < self.n and not touched[p], f"{self.name}: stage {si} overlap at {p}"
                    touched[p] = True

    def depth(self) -> int:
        return len(self.stages)

    def to_json(self) -> dict:
        j = {
            "name": self.name,
            "kind": self.kind,
            "list_sizes": self.list_sizes,
            "input_map": self.input_map,
            "n": self.n,
            "stages": [s.to_json() for s in self.stages],
            "output_perm": self.output_perm,
        }
        if self.median_tap is not None:
            j["median_tap"] = list(self.median_tap)
        if self.grid is not None:
            j["grid"] = list(self.grid)
        return j

    def to_json_str(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=True)

    # ------------------------------------------------------------------
    # Reference execution (the Python oracle of hardware semantics).
    # ------------------------------------------------------------------
    def load_inputs(self, lists: list[list[int]]) -> list[int]:
        v = [0] * self.n
        for l, lst in enumerate(lists):
            assert len(lst) == self.list_sizes[l]
            for i, x in enumerate(lst):
                v[self.input_map[l][i]] = x
        return v

    def run(self, v: list[int], stop_after: int | None = None) -> None:
        for stage in self.stages[: stop_after if stop_after is not None else len(self.stages)]:
            for b in stage.blocks:
                if isinstance(b, Cas):
                    if v[b.lo] > v[b.hi]:
                        v[b.lo], v[b.hi] = v[b.hi], v[b.lo]
                elif isinstance(b, SortN):
                    vals = sorted(v[p] for p in b.pos)
                    for i, p in enumerate(b.pos):
                        v[p] = vals[i]
                elif isinstance(b, MergeS2):
                    vals = sorted(v[p] for p in b.reads())
                    for i, p in enumerate(b.out):
                        v[p] = vals[i]
                elif isinstance(b, FilterN):
                    vals = sorted(v[p] for p in b.pos)
                    for t in b.taps:
                        v[b.pos[t]] = vals[t]

    def merge(self, lists: list[list[int]]) -> list[int]:
        v = self.load_inputs(lists)
        self.run(v)
        return [v[p] for p in self.output_perm]


def validate_merge_01(d: MergeDevice) -> None:
    """Exhaustive sorted-0-1 validation (see the Rust twin for theory)."""
    d.check()
    sizes = d.list_sizes
    zeros = [0] * len(sizes)
    while True:
        lists = [[0] * z + [1] * (s - z) for s, z in zip(sizes, zeros)]
        out = d.merge(lists)
        assert all(out[i] <= out[i + 1] for i in range(len(out) - 1)), (
            f"{d.name}: unsorted output for {lists}"
        )
        if d.median_tap is not None:
            stop, pos = d.median_tap
            v = d.load_inputs(lists)
            d.run(v, stop_after=stop)
            flat = sorted(x for lst in lists for x in lst)
            assert v[pos] == flat[len(flat) // 2], f"{d.name}: median tap wrong for {lists}"
        i = 0
        while True:
            if i == len(sizes):
                return
            zeros[i] += 1
            if zeros[i] <= sizes[i]:
                break
            zeros[i] = 0
            i += 1
