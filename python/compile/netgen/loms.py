"""List Offset Merge Sorter construction — Python mirror of
``rust/src/sortnet/loms.rs`` (see that file and paper §IV/§V/App. A for
the construction; conventions are identical: row 0 = bottom, col 0 =
rightmost, flat positions in final-output scan order)."""

from __future__ import annotations

from dataclasses import dataclass

from .device import Cas, MergeDevice, MergeS2, SortN, Stage


@dataclass
class SetupArray:
    rows: int
    cols: int
    # grid[row][col] = (list, idx, pos) or None
    grid: list[list[tuple[int, int, int] | None]]
    serpentine: bool
    list_sizes: list[int]

    def scan_cols(self, row: int) -> list[int]:
        if self.serpentine and row % 2 == 1:
            return list(range(self.cols - 1, -1, -1))
        return list(range(self.cols))

    def input_map(self) -> list[list[int]]:
        m = [[-1] * s for s in self.list_sizes]
        for row in self.grid:
            for cell in row:
                if cell is not None:
                    l, i, p = cell
                    m[l][i] = p
        return m

    def column(self, c: int) -> list[tuple[int, int, int]]:
        return [self.grid[r][c] for r in range(self.rows) if self.grid[r][c] is not None]

    def row_scan(self, r: int) -> list[tuple[int, int, int]]:
        return [self.grid[r][c] for c in self.scan_cols(r) if self.grid[r][c] is not None]


def _finish(staged, cols: int, sizes: list[int], serpentine: bool) -> SetupArray:
    r0 = len(staged)
    slid: list[list[tuple[int, int] | None]] = [[None] * cols for _ in range(r0)]
    for c in range(cols):
        vals = [staged[r][c] for r in range(r0) if staged[r][c] is not None]
        h = len(vals)
        for i, v in enumerate(vals):
            slid[r0 - h + i][c] = v
    first = next(r for r in range(r0) if any(x is not None for x in slid[r]))
    rows = r0 - first
    arr = SetupArray(rows, cols, [[None] * cols for _ in range(rows)], serpentine, sizes)
    pos = 0
    for r in range(rows):
        for c in arr.scan_cols(r):
            cell = slid[first + r][c]
            if cell is not None:
                arr.grid[r][c] = (cell[0], cell[1], pos)
                pos += 1
    return arr


def setup_2way(m: int, n: int, cols: int) -> SetupArray:
    assert cols >= 2 and m + n >= 1
    ra = -(-m // cols)
    rb = -(-n // cols)
    r0 = ra + rb
    staged: list[list[tuple[int, int] | None]] = [[None] * cols for _ in range(r0)]
    for d in range(m):
        staged[r0 - 1 - d // cols][cols - 1 - d % cols] = (0, m - 1 - d)
    for d in range(n):
        staged[rb - 1 - d // cols][d % cols] = (1, n - 1 - d)
    return _finish(staged, cols, [m, n], False)


def setup_kway(sizes: list[int]) -> SetupArray:
    k = len(sizes)
    assert k >= 2
    rows_per = [-(-s // k) for s in sizes]
    r0 = sum(rows_per)
    staged: list[list[tuple[int, int] | None]] = [[None] * k for _ in range(r0)]
    top = r0
    for l, s in enumerate(sizes):
        band_top = top - 1
        for d in range(s):
            r = band_top - d // k
            c = (k - 1 - l - d % k) % k
            assert staged[r][c] is None
            staged[r][c] = (l, s - 1 - d)
        top -= rows_per[l]
    return _finish(staged, k, list(sizes), k >= 3)


def _column_sort_stage(arr: SetupArray) -> Stage:
    blocks = []
    for c in range(arr.cols):
        cells = arr.column(c)
        if len(cells) < 2:
            continue
        out = tuple(x[2] for x in cells)
        if len(arr.list_sizes) == 2:
            up = tuple(x[2] for x in cells if x[0] == 0)
            dn = tuple(x[2] for x in cells if x[0] == 1)
            if not up or not dn:
                continue
            blocks.append(MergeS2(up, dn, out))
        else:
            if len({x[0] for x in cells}) <= 1:
                continue
            blocks.append(SortN(out))
    return Stage("col-sort", blocks)


def _row_sort_stage(arr: SetupArray, label: str = "row-sort") -> Stage:
    blocks = []
    for r in range(arr.rows):
        pos = tuple(x[2] for x in arr.row_scan(r))
        if len(pos) < 2:
            continue
        blocks.append(Cas(pos[0], pos[1]) if len(pos) == 2 else SortN(pos))
    return Stage(label, blocks)


def _full_column_stage(arr: SetupArray) -> Stage:
    blocks = []
    for c in range(arr.cols):
        cells = arr.column(c)
        if len(cells) >= 2:
            blocks.append(SortN(tuple(x[2] for x in cells)))
    return Stage("col-sort", blocks)


def _edge_pair_stage(arr: SetupArray) -> Stage:
    k = arr.cols
    blocks = []

    def pos(c, r):
        cell = arr.grid[r][c]
        return None if cell is None else cell[2]

    r = 0
    while r + 1 < arr.rows:
        lo, hi = pos(k - 1, r), pos(k - 1, r + 1)
        if lo is not None and hi is not None:
            blocks.append(Cas(lo, hi))
        r += 2
    r = 1
    while r + 1 < arr.rows:
        lo, hi = pos(0, r), pos(0, r + 1)
        if lo is not None and hi is not None:
            blocks.append(Cas(lo, hi))
        r += 2
    return Stage("edge-pair-sort", blocks)


def table1_stage_count(k: int) -> int:
    if k <= 1:
        return 0
    if k == 2:
        return 2
    if k == 3:
        return 3
    if k in (4, 5):
        return 4
    if k == 6:
        return 5
    if k <= 14:
        return 6
    import math

    return 6 + math.ceil(math.log2(k / 7.0))


def loms_2way(m: int, n: int, cols: int) -> MergeDevice:
    arr = setup_2way(m, n, cols)
    total = m + n
    stages = [s for s in (_column_sort_stage(arr), _row_sort_stage(arr)) if s.blocks]
    return MergeDevice(
        name=f"loms2-{cols}col-up{m}-dn{n}",
        kind="loms",
        list_sizes=[m, n],
        input_map=arr.input_map(),
        n=total,
        stages=stages,
        output_perm=list(range(total)),
        grid=(arr.cols, arr.rows),
    )


def loms_kway(sizes: list[int]) -> MergeDevice:
    k = len(sizes)
    assert k >= 3
    arr = setup_kway(sizes)
    total = sum(sizes)
    n_stages = table1_stage_count(k)
    full_grid = (
        total == arr.rows * arr.cols
        and all(s == sizes[0] for s in sizes)
        and sizes[0] % 2 == 1
    )
    stages = [_column_sort_stage(arr), _row_sort_stage(arr)]
    for s in range(2, n_stages):
        if s % 2 == 0:
            if k == 3 and full_grid and s == 2:
                stages.append(_edge_pair_stage(arr))
            else:
                stages.append(_full_column_stage(arr))
        else:
            stages.append(_row_sort_stage(arr))
    stages = [s for s in stages if s.blocks]
    equal_odd = k == 3 and all(s == sizes[0] for s in sizes) and sizes[0] % 2 == 1
    median_tap = (min(2, len(stages)), total // 2) if equal_odd and total % 2 == 1 else None
    return MergeDevice(
        name=f"loms{k}-{'_'.join(map(str, sizes))}r",
        kind="loms",
        list_sizes=list(sizes),
        input_map=arr.input_map(),
        n=total,
        stages=stages,
        output_perm=list(range(total)),
        median_tap=median_tap,
        grid=(arr.cols, arr.rows),
    )
