"""Compile-time network construction (Python mirror of rust sortnet).

Cross-checked against the Rust implementation via golden JSON vectors —
see python/tests/test_golden.py and `loms netgen --golden`.
"""

from . import batcher, device, loms, s2ms
from .device import Cas, FilterN, MergeDevice, MergeS2, SortN, Stage

__all__ = [
    "batcher", "device", "loms", "s2ms",
    "Cas", "FilterN", "MergeDevice", "MergeS2", "SortN", "Stage",
]
