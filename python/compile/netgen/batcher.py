"""Batcher Odd-Even and Bitonic merge networks (Python mirror of
``rust/src/sortnet/batcher.rs``) — the CAS-stage baselines the kernels
compile for comparison against the LOMS rank kernels."""

from __future__ import annotations

from .device import Cas, MergeDevice, Stage


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def _odd_even_merge_stages(idx: list[int]) -> list[list[tuple[int, int]]]:
    n = len(idx)
    assert _is_pow2(n) and n >= 2
    if n == 2:
        return [[(idx[0], idx[1])]]
    even = idx[0::2]
    odd = idx[1::2]
    se = _odd_even_merge_stages(even)
    so = _odd_even_merge_stages(odd)
    stages = [e + o for e, o in zip(se, so)]
    stages.append([(idx[2 * i + 1], idx[2 * i + 2]) for i in range(n // 2 - 1)])
    return stages


def _bitonic_merge_stages(idx: list[int]) -> list[list[tuple[int, int]]]:
    n = len(idx)
    assert _is_pow2(n) and n >= 2
    stages = []
    span = n // 2
    while span >= 1:
        stage = []
        block = 0
        while block < n:
            for i in range(block, block + span):
                stage.append((idx[i], idx[i + span]))
            block += 2 * span
        stages.append(stage)
        span //= 2
    return stages


def _device(name: str, kind: str, m: int, input_map: list[list[int]], cas) -> MergeDevice:
    n = 2 * m
    stages = [
        Stage(f"cas-{i}", [Cas(lo, hi) for lo, hi in pairs]) for i, pairs in enumerate(cas)
    ]
    return MergeDevice(
        name=name,
        kind=kind,
        list_sizes=[m, m],
        input_map=input_map,
        n=n,
        stages=stages,
        output_perm=list(range(n)),
    )


def odd_even_merge(m: int) -> MergeDevice:
    """Batcher odd-even 2-way merge of two sorted power-of-2 lists."""
    assert _is_pow2(m)
    n = 2 * m
    return _device(
        f"oem-up{m}-dn{m}",
        "odd_even_merge",
        m,
        [list(range(m)), list(range(m, n))],
        _odd_even_merge_stages(list(range(n))),
    )


def bitonic_merge(m: int) -> MergeDevice:
    """Batcher bitonic 2-way merge (B list loaded reversed)."""
    assert _is_pow2(m)
    n = 2 * m
    return _device(
        f"bims-up{m}-dn{m}",
        "bitonic_merge",
        m,
        [list(range(m)), list(range(n - 1, m - 1, -1))],
        _bitonic_merge_stages(list(range(n))),
    )


def sortn_cas_stages(pos: list[int]) -> list[list[tuple[int, int]]]:
    """Odd-even transposition sort network over arbitrary-width ``pos`` —
    used to lower SortN row sorters into CAS stages for the kernels.
    Depth = len(pos) rounds (fine: LOMS rows are ≤ 8 wide)."""
    n = len(pos)
    if n < 2:
        return []
    stages = []
    for r in range(n):
        pairs = []
        start = r % 2
        i = start
        while i + 1 < n:
            pairs.append((pos[i], pos[i + 1]))
            i += 2
        if pairs:
            stages.append(pairs)
    return stages
