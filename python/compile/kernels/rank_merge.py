"""Rank-select merge — the TPU analogue of the paper's Single-Stage
2-way Merge Sorter (S2MS).

The hardware S2MS computes all ``m*n`` cross comparison signals in
parallel and routes every input straight to its output rank through a
multiplexer tree (Fig. 9). The vectorised analogue computes the same
comparator bank as one broadcast compare, derives each element's output
*rank* (its index plus the count of cross elements ahead of it), and
places elements with a one-hot matmul-style scatter — **one parallel
stage**, versus the log-depth compare-exchange cascade of a Batcher
network. This is the stage-count trade the paper's figures measure,
re-expressed in vector-op depth (DESIGN.md §3 Hardware-Adaptation).

Stability matches the hardware (and the Rust exec): UP values win ties.
"""

from __future__ import annotations

import jax.numpy as jnp


def merge_ranks(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Output ranks for merging sorted ``a`` (..., m) with sorted ``b``
    (..., n): rank_a[i] = i + #{b < a_i}, rank_b[j] = j + #{a <= b_j}.

    The two broadcast comparisons are exactly the S2MS ``ge_*``
    comparator bank."""
    m = a.shape[-1]
    n = b.shape[-1]
    # (..., m, n) comparator bank.
    b_lt_a = (b[..., None, :] < a[..., :, None]).astype(jnp.int32)
    a_le_b = (a[..., :, None] <= b[..., None, :]).astype(jnp.int32)
    rank_a = jnp.arange(m, dtype=jnp.int32) + b_lt_a.sum(axis=-1)
    rank_b = jnp.arange(n, dtype=jnp.int32) + a_le_b.sum(axis=-2)
    return rank_a, rank_b


def rank_merge_onehot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """One-hot placement — the MXU-shaped form (a matmul against a
    one-hot matrix), the closest analogue of the hardware mux tree.
    O(n²) multiply-adds per merge: ideal for a systolic array, ~30%
    slower than the scatter form on the CPU PJRT backend (§Perf)."""
    m = a.shape[-1]
    n = b.shape[-1]
    total = m + n
    rank_a, rank_b = merge_ranks(a, b)
    slots = jnp.arange(total, dtype=jnp.int32)
    onehot_a = (rank_a[..., :, None] == slots).astype(a.dtype)
    onehot_b = (rank_b[..., :, None] == slots).astype(b.dtype)
    return (a[..., :, None] * onehot_a).sum(axis=-2) + (b[..., :, None] * onehot_b).sum(axis=-2)


def rank_merge_scatter(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Scatter placement: ranks are a permutation, so the two scatters
    never collide. Faster than one-hot on the CPU backend (§Perf:
    41.6 µs vs 58.5 µs per 64×(32+32) batch)."""
    import jax

    m = a.shape[-1]
    n = b.shape[-1]
    total = m + n
    rank_a, rank_b = merge_ranks(a, b)
    lead = a.shape[:-1]
    out = jnp.zeros((*lead, total), a.dtype)

    def place(o, r, v):
        return o.at[r].set(v)

    out = jax.vmap(place)(out.reshape(-1, total), rank_a.reshape(-1, m), a.reshape(-1, m))
    out = jax.vmap(place)(out, rank_b.reshape(-1, n), b.reshape(-1, n))
    return out.reshape(*lead, total)


# Default implementation (selected by the §Perf pass for the CPU PJRT
# deployment target; switch to `rank_merge_onehot` for MXU targets).
rank_merge = rank_merge_scatter
