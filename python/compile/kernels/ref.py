"""Pure-jnp correctness oracles for the merge kernels.

``merge_ref`` is the ground truth every kernel variant must match
bit-exactly (pytest asserts exact equality — values are u32 and merging
is exact)."""

from __future__ import annotations

import jax.numpy as jnp


def merge_ref(lists: list[jnp.ndarray]) -> jnp.ndarray:
    """Merge k batched sorted lists: each (B, s_l) -> (B, sum s_l) sorted."""
    return jnp.sort(jnp.concatenate(lists, axis=-1), axis=-1)


def median_ref(lists: list[jnp.ndarray]) -> jnp.ndarray:
    """Median of the merged values per batch row (odd totals)."""
    merged = merge_ref(lists)
    total = merged.shape[-1]
    assert total % 2 == 1, "median oracle expects odd totals"
    return merged[..., total // 2]
