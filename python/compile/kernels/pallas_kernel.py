"""Pallas kernel wrapping a merge plan (Layer 1).

The whole setup array for one batch block lives in VMEM: inputs are
blocked over the batch dimension via ``BlockSpec`` (the HBM↔VMEM
schedule), and the plan's steps run as VPU-friendly min/max/select and
MXU-shaped one-hot placements inside the kernel body. ``interpret=True``
is mandatory in this environment: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md), while interpret mode lowers to plain HLO
that runs on any backend — numerics are identical.

VMEM budget: a block holds ``block_b × total`` u32 values per list plus
the flat working vector — for the largest AOT variant (UP-128/DN-128,
block 64) that is 64×256×4 B × ~3 ≈ 200 KiB, comfortably inside the
~16 MiB/core VMEM of a real TPU (DESIGN.md §Perf records the footprint
per artifact).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..netgen.device import MergeDevice
from .plan import apply_plan, constants, lower


def make_pallas_merge(device: MergeDevice, batch: int, mode: str = "rank", block_b: int = 32):
    """Build ``f(*lists) -> merged`` executing the device's plan as a
    Pallas kernel blocked over the batch dimension.

    The plan's static index/mask arrays are passed as kernel inputs
    (Pallas rejects captured array constants); their BlockSpecs map every
    grid step to the whole (small) array."""
    steps = lower(device, mode)
    total = device.n
    block_b = min(block_b, batch)
    assert batch % block_b == 0, "batch must be a multiple of the block size"
    consts = constants(device, steps)
    n_lists = len(device.list_sizes)

    def kernel(*refs):
        in_refs = refs[:n_lists]
        const_refs = refs[n_lists:-1]
        o_ref = refs[-1]
        lists = [r[...] for r in in_refs]
        o_ref[...] = apply_plan(device, steps, lists, [r[...] for r in const_refs])

    grid = (batch // block_b,)
    in_specs = [pl.BlockSpec((block_b, s), lambda i: (i, 0)) for s in device.list_sizes]
    in_specs += [
        pl.BlockSpec(c.shape, (lambda nd: (lambda i: (0,) * nd))(c.ndim)) for c in consts
    ]
    out_spec = pl.BlockSpec((block_b, total), lambda i: (i, 0))

    def f(*lists):
        assert len(lists) == n_lists
        for x, s in zip(lists, device.list_sizes):
            assert x.shape == (batch, s), f"expected ({batch},{s}), got {x.shape}"
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((batch, total), lists[0].dtype),
            interpret=True,
        )(*lists, *[jnp.asarray(c) for c in consts])

    return f


def vmem_bytes(device: MergeDevice, block_b: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one kernel invocation: input blocks
    + flat vector + output block."""
    per_row = sum(device.list_sizes) + 2 * device.n
    return block_b * per_row * dtype_bytes
