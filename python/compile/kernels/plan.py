"""Lowering of a netgen ``MergeDevice`` into a vectorised execution plan.

Two lowering modes mirror the paper's device families:

* ``mode="rank"`` (LOMS/S2MS style): ``MergeS2`` blocks stay as
  single-stage rank-select merges ([`rank_merge`]); ``SortN``/``Cas``
  blocks become compare-exchange steps. A 2-way LOMS lowers to
  *column rank-merge → row CAS* — exactly the paper's 2 stages.
* ``mode="cas"`` (Batcher style): everything, including ``MergeS2``,
  lowers to compare-exchange stages (odd-even networks) — the log-depth
  baseline.

Each plan step is dense vector work over the whole batch with all
indices static, so the plan traces into a single fused XLA computation
(and into a Pallas kernel body — see ``pallas_kernel.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..netgen.batcher import _odd_even_merge_stages, sortn_cas_stages
from ..netgen.device import Cas, FilterN, MergeDevice, MergeS2, SortN
from .rank_merge import rank_merge


@dataclass(frozen=True)
class CasStep:
    """One compare-exchange stage over the flat vector: position p takes
    min(x[p], x[partner[p]]) where min_mask[p], else max. Untouched
    positions have partner[p] == p."""

    partner: tuple[int, ...]
    min_mask: tuple[bool, ...]


@dataclass(frozen=True)
class RankMergeStep:
    """A group of same-shape S2MS blocks executed as one batched
    rank-select merge: gather (g, m) + (g, n), merge, scatter (g, m+n)."""

    up_idx: tuple[tuple[int, ...], ...]
    dn_idx: tuple[tuple[int, ...], ...]
    out_idx: tuple[tuple[int, ...], ...]


Step = CasStep | RankMergeStep


def _cas_stage_to_step(n: int, pairs: list[tuple[int, int]]) -> CasStep:
    partner = list(range(n))
    min_mask = [True] * n
    for lo, hi in pairs:
        partner[lo] = hi
        partner[hi] = lo
        min_mask[lo] = True
        min_mask[hi] = False
    return CasStep(tuple(partner), tuple(min_mask))


def _block_cas_stages(b, mode: str) -> list[list[tuple[int, int]]]:
    """CAS-stage expansion of one block (used when the block is not kept
    as a rank-merge)."""
    if isinstance(b, Cas):
        return [[(b.lo, b.hi)]]
    if isinstance(b, (SortN, FilterN)):
        return sortn_cas_stages(list(b.pos))
    if isinstance(b, MergeS2):
        # Odd-even merge needs the merged sequence laid out in out-order
        # with the two runs as its halves; arbitrary sizes fall back to a
        # transposition sort over out positions.
        total = len(b.up) + len(b.dn)
        seq = list(b.up) + list(b.dn)
        if len(b.up) == len(b.dn) and total & (total - 1) == 0:
            stages = _odd_even_merge_stages(seq)
            # After the odd-even merge, rank t sits at seq[t]; route to
            # out positions. seq and out are permutations of the same
            # set; if they differ we add no comparator — the plan's final
            # gather handles it only if out==seq. LOMS column sorters
            # always satisfy out == up++dn in row order... if not, sort
            # transpositions are used instead.
            if seq == list(b.out):
                return stages
        return sortn_cas_stages(list(b.out))
    raise TypeError(b)


def lower(device: MergeDevice, mode: str = "rank") -> list[Step]:
    """Lower a device into plan steps."""
    assert mode in ("rank", "cas")
    steps: list[Step] = []
    for stage in device.stages:
        rank_blocks: list[MergeS2] = []
        cas_blocks = []
        for b in stage.blocks:
            if mode == "rank" and isinstance(b, MergeS2):
                rank_blocks.append(b)
            else:
                cas_blocks.append(b)
        # Group rank blocks by shape so each group is one batched merge.
        groups: dict[tuple[int, int], list[MergeS2]] = {}
        for b in rank_blocks:
            groups.setdefault((len(b.up), len(b.dn)), []).append(b)
        for (_m, _n), blocks in sorted(groups.items()):
            steps.append(
                RankMergeStep(
                    tuple(b.up for b in blocks),
                    tuple(b.dn for b in blocks),
                    tuple(b.out for b in blocks),
                )
            )
        # Lower the remaining blocks to CAS stages run in lockstep.
        expanded = [_block_cas_stages(b, mode) for b in cas_blocks]
        depth = max((len(e) for e in expanded), default=0)
        for level in range(depth):
            pairs = [p for e in expanded if level < len(e) for p in e[level]]
            if pairs:
                steps.append(_cas_stage_to_step(device.n, pairs))
    return steps


def input_gather(device: MergeDevice) -> tuple[int, ...]:
    """gather index g: flat[p] = concat_inputs[g[p]] where the concat is
    list 0 ascending, list 1 ascending, ..."""
    g = [0] * device.n
    src = 0
    for m in device.input_map:
        for p in m:
            g[p] = src
            src += 1
    return tuple(g)


def constants(device: MergeDevice, steps: list[Step]) -> list[np.ndarray]:
    """All static index/mask arrays the plan needs, in execution order.

    Kept separate from ``apply_plan`` so the Pallas wrapper can pass them
    as kernel *inputs* (Pallas forbids captured array constants) while
    the plain-jnp path closes over them."""
    arrs: list[np.ndarray] = [np.array(input_gather(device), dtype=np.int32)]
    for step in steps:
        if isinstance(step, CasStep):
            arrs.append(np.array(step.partner, dtype=np.int32))
            arrs.append(np.array(step.min_mask, dtype=np.int8))
        else:
            arrs.append(np.array(step.up_idx, dtype=np.int32))
            arrs.append(np.array(step.dn_idx, dtype=np.int32))
            arrs.append(np.array(step.out_idx, dtype=np.int32))
    arrs.append(np.array(device.output_perm, dtype=np.int32))
    return arrs


def apply_plan(
    device: MergeDevice,
    steps: list[Step],
    lists: list[jnp.ndarray],
    consts: list[jnp.ndarray] | None = None,
) -> jnp.ndarray:
    """Execute the plan over batched inputs (each (B, s_l)) → (B, total)."""
    it = iter(consts if consts is not None else constants(device, steps))
    x = jnp.concatenate(lists, axis=-1)[:, next(it)]
    for step in steps:
        if isinstance(step, CasStep):
            partner = next(it)
            mask = next(it)
            xp = x[:, partner]
            x = jnp.where(mask != 0, jnp.minimum(x, xp), jnp.maximum(x, xp))
        else:
            up = next(it)  # (g, m)
            dn = next(it)  # (g, n)
            out = next(it)  # (g, m+n)
            a = x[:, up]  # (B, g, m)
            b = x[:, dn]  # (B, g, n)
            merged = rank_merge(a, b)  # (B, g, m+n)
            x = x.at[:, out.reshape(-1)].set(merged.reshape(x.shape[0], -1))
    return x[:, next(it)]


def merge_fn(device: MergeDevice, mode: str = "rank"):
    """Build a jit-able ``f(*lists) -> merged`` for the device."""
    steps = lower(device, mode)

    def f(*lists):
        return apply_plan(device, steps, list(lists))

    return f


def plan_stats(steps: list[Step]) -> dict:
    """Structural stats: sequential vector-op depth per kind (the TPU
    analogue of the paper's stage counts)."""
    return {
        "steps": len(steps),
        "cas_steps": sum(isinstance(s, CasStep) for s in steps),
        "rank_steps": sum(isinstance(s, RankMergeStep) for s in steps),
    }
