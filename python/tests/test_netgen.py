"""Construction tests for the Python netgen mirror (paper figures +
exhaustive sorted-0-1 validation)."""

import pytest

from compile.netgen import batcher, loms, s2ms
from compile.netgen.device import validate_merge_01


def grid_as_paper(arr):
    """Top row first, leftmost column first, (list, idx) cells."""
    return [
        [
            (arr.grid[r][c][0], arr.grid[r][c][1]) if arr.grid[r][c] else None
            for c in range(arr.cols - 1, -1, -1)
        ]
        for r in range(arr.rows - 1, -1, -1)
    ]


def test_fig1_up8_dn8_setup():
    a = lambda i: (0, i)
    b = lambda i: (1, i)
    assert grid_as_paper(loms.setup_2way(8, 8, 2)) == [
        [a(7), a(6)],
        [a(5), a(4)],
        [a(3), a(2)],
        [a(1), a(0)],
        [b(6), b(7)],
        [b(4), b(5)],
        [b(2), b(3)],
        [b(0), b(1)],
    ]


def test_fig2_up1_dn8_setup():
    b = lambda i: (1, i)
    assert grid_as_paper(loms.setup_2way(1, 8, 2)) == [
        [(0, 0), b(7)],
        [b(6), b(5)],
        [b(4), b(3)],
        [b(2), b(1)],
        [b(0), None],
    ]


def test_fig23_3c7r_setup():
    a = lambda i: (0, i)
    b = lambda i: (1, i)
    c = lambda i: (2, i)
    assert grid_as_paper(loms.setup_kway([7, 7, 7])) == [
        [a(6), a(5), a(4)],
        [a(3), a(2), a(1)],
        [a(0), b(6), b(5)],
        [b(4), b(3), b(2)],
        [b(1), b(0), c(6)],
        [c(5), c(4), c(3)],
        [c(2), c(1), c(0)],
    ]


def test_fig6_worked_example():
    d = loms.loms_kway([7, 7, 7])
    out = d.merge([list(range(1, 8)), list(range(8, 15)), list(range(15, 22))])
    assert out == list(range(1, 22))


@pytest.mark.parametrize("m,n", [(1, 1), (1, 8), (8, 1), (7, 5), (8, 8), (16, 16), (9, 3)])
@pytest.mark.parametrize("cols", [2, 4])
def test_loms_2way_validates(m, n, cols):
    validate_merge_01(loms.loms_2way(m, n, cols))


@pytest.mark.parametrize("sizes", [[7, 7, 7], [5, 5, 5], [3, 3, 3], [4, 4, 4], [7, 5, 3]])
def test_loms_kway_validates(sizes):
    validate_merge_01(loms.loms_kway(sizes))


@pytest.mark.parametrize("m", [1, 2, 4, 8, 16])
def test_batcher_validates(m):
    validate_merge_01(batcher.odd_even_merge(m))
    validate_merge_01(batcher.bitonic_merge(m))


@pytest.mark.parametrize("m,n", [(2, 2), (7, 5), (16, 16)])
def test_s2ms_validates(m, n):
    validate_merge_01(s2ms.s2ms(m, n))


def test_loms_depths():
    assert loms.loms_2way(32, 32, 2).depth() == 2
    assert loms.loms_kway([7, 7, 7]).depth() == 3
    assert loms.loms_kway([7, 7, 7]).median_tap == (2, 10)


def test_table1():
    assert [loms.table1_stage_count(k) for k in range(2, 8)] == [2, 3, 4, 4, 5, 6]
