"""Kernel correctness: plans and Pallas kernels vs the pure-jnp oracle.

Exact (bit-for-bit) equality is required — merging u32 keys is exact.
Hypothesis drives shapes, duplicates and extreme values.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import plan as P
from compile.kernels.pallas_kernel import make_pallas_merge, vmem_bytes
from compile.kernels.rank_merge import rank_merge
from compile.kernels.ref import median_ref, merge_ref
from compile.netgen import batcher, loms, s2ms


def sorted_rows(rng, b, s, hi=1000):
    return jnp.asarray(np.sort(rng.integers(0, hi, size=(b, s), dtype=np.uint32), axis=-1))


@pytest.mark.parametrize(
    "dev_fn,mode",
    [
        (lambda: loms.loms_2way(8, 8, 2), "rank"),
        (lambda: loms.loms_2way(8, 8, 2), "cas"),
        (lambda: loms.loms_2way(32, 32, 2), "rank"),
        (lambda: loms.loms_2way(32, 32, 8), "rank"),
        (lambda: loms.loms_2way(7, 5, 2), "rank"),
        (lambda: batcher.odd_even_merge(16), "cas"),
        (lambda: batcher.bitonic_merge(8), "cas"),
        (lambda: s2ms.s2ms(32, 32), "rank"),
        (lambda: loms.loms_kway([7, 7, 7]), "rank"),
        (lambda: loms.loms_kway([5, 5, 5]), "cas"),
    ],
)
def test_plan_matches_ref(dev_fn, mode):
    dev = dev_fn()
    rng = np.random.default_rng(42)
    f = P.merge_fn(dev, mode)
    lists = [sorted_rows(rng, 9, s) for s in dev.list_sizes]
    got = f(*lists)
    assert (got == merge_ref(lists)).all(), dev.name


@given(
    m=st.integers(1, 24),
    n=st.integers(1, 24),
    b=st.integers(1, 5),
    seed=st.integers(0, 2**32 - 1),
)
@settings(max_examples=60, deadline=None)
def test_rank_merge_hypothesis(m, n, b, seed):
    rng = np.random.default_rng(seed)
    a = sorted_rows(rng, b, m, hi=7)  # small range → many duplicates
    bb = sorted_rows(rng, b, n, hi=7)
    got = rank_merge(a, bb)
    assert (got == merge_ref([a, bb])).all()


@given(
    m=st.integers(1, 12),
    n=st.integers(1, 12),
    cols=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**32 - 1),
)
@settings(max_examples=40, deadline=None)
def test_loms_plan_hypothesis(m, n, cols, seed):
    rng = np.random.default_rng(seed)
    dev = loms.loms_2way(m, n, cols)
    f = P.merge_fn(dev, "rank")
    lists = [sorted_rows(rng, 4, s, hi=50) for s in dev.list_sizes]
    assert (f(*lists) == merge_ref(lists)).all()


def test_extreme_values():
    dev = loms.loms_2way(8, 8, 2)
    f = P.merge_fn(dev, "rank")
    a = jnp.asarray(np.array([[0] * 4 + [2**32 - 1] * 4], dtype=np.uint32))
    b = jnp.asarray(np.array([[0] * 8], dtype=np.uint32))
    got = f(a, b)
    assert (got == merge_ref([a, b])).all()


def test_rank_and_cas_modes_agree():
    rng = np.random.default_rng(7)
    dev = loms.loms_2way(16, 16, 2)
    lists = [sorted_rows(rng, 8, 16) for _ in range(2)]
    assert (P.merge_fn(dev, "rank")(*lists) == P.merge_fn(dev, "cas")(*lists)).all()


def test_plan_depth_reflects_paper_story():
    # The TPU re-expression of the paper's stage counts: S2MS = 1 step,
    # LOMS-2col = 2 steps, Batcher 64-out = 6 steps.
    assert P.plan_stats(P.lower(s2ms.s2ms(32, 32), "rank"))["steps"] == 1
    assert P.plan_stats(P.lower(loms.loms_2way(32, 32, 2), "rank"))["steps"] == 2
    assert P.plan_stats(P.lower(batcher.odd_even_merge(32), "cas"))["steps"] == 6


@pytest.mark.parametrize("block_b", [8, 16, 32, 64])
def test_pallas_blocking(block_b):
    rng = np.random.default_rng(3)
    dev = loms.loms_2way(32, 32, 2)
    f = make_pallas_merge(dev, 64, "rank", block_b)
    lists = [sorted_rows(rng, 64, 32) for _ in range(2)]
    assert (f(*lists) == merge_ref(lists)).all()


def test_pallas_3way_and_median():
    rng = np.random.default_rng(5)
    dev = loms.loms_kway([7, 7, 7])
    f = make_pallas_merge(dev, 32, "rank", 32)
    lists = [sorted_rows(rng, 32, 7) for _ in range(3)]
    merged = f(*lists)
    assert (merged == merge_ref(lists)).all()
    assert (merged[:, 10] == median_ref(lists)).all()


def test_vmem_budget_documented():
    dev = loms.loms_2way(256, 256, 8)
    assert vmem_bytes(dev, 4) < 16 * 2**20, "block must fit a TPU core's VMEM"


@given(
    m=st.integers(1, 20),
    n=st.integers(1, 20),
    b=st.integers(1, 4),
    seed=st.integers(0, 2**32 - 1),
)
@settings(max_examples=40, deadline=None)
def test_scatter_and_onehot_rank_merge_agree(m, n, b, seed):
    # The two placements (scatter: CPU-fast; one-hot: MXU-shaped) must be
    # interchangeable bit-for-bit (§Perf keeps both).
    from compile.kernels.rank_merge import rank_merge_onehot, rank_merge_scatter

    rng = np.random.default_rng(seed)
    a = sorted_rows(rng, b, m, hi=9)
    bb = sorted_rows(rng, b, n, hi=9)
    assert (rank_merge_scatter(a, bb) == rank_merge_onehot(a, bb)).all()


def test_pallas_batch256_block128_variant():
    # The §Perf-selected production shape for the 32+32 artifact.
    rng = np.random.default_rng(8)
    dev = loms.loms_2way(32, 32, 2)
    f = make_pallas_merge(dev, 256, "rank", 128)
    lists = [sorted_rows(rng, 256, 32) for _ in range(2)]
    assert (f(*lists) == merge_ref(lists)).all()
