"""AOT pipeline tests: every variant lowers to valid HLO text; the
manifest metadata matches the devices."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_variant, to_hlo_text
from compile.kernels.ref import merge_ref
from compile.model import VARIANTS, example_args


@pytest.mark.parametrize("name", sorted(VARIANTS))
def test_variant_builds_and_matches_ref(name):
    v = VARIANTS[name]
    f = v.build()
    args = example_args(v)
    got = f(*args)
    assert got.dtype == jnp.uint32
    assert (got == merge_ref(args)).all(), name


@pytest.mark.parametrize("name", ["loms2_up32_dn32_b256", "loms3_7r_b256"])
def test_variant_lowers_to_hlo_text(name):
    text = lower_variant(VARIANTS[name])
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # Pallas interpret mode must have lowered to plain HLO: no Mosaic
    # custom-calls the CPU PJRT client cannot run.
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_meta_consistency():
    for name, v in VARIANTS.items():
        meta = v.meta()
        assert meta["name"] == name
        assert meta["total"] == sum(meta["list_sizes"])
        assert meta["dtype"] == "u32"
        assert meta["batch"] % meta["block_b"] == 0 or meta["block_b"] >= meta["batch"]


def test_manifest_on_disk_if_built():
    man = pathlib.Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
    if not man.exists():
        pytest.skip("artifacts not built")
    j = json.loads(man.read_text())
    names = {a["name"] for a in j["artifacts"]}
    assert set(VARIANTS) <= names or names <= set(VARIANTS)
    for a in j["artifacts"]:
        assert (man.parent / a["file"]).exists()


def test_round_trip_jit_executes_like_eager():
    v = VARIANTS["loms2_up32_dn32_b256"]
    f = v.build()
    args = example_args(v)
    eager = f(*args)
    jitted = jax.jit(f)(*args)
    assert (np.asarray(eager) == np.asarray(jitted)).all()
