"""Cross-implementation golden check: the Python netgen constructions
must match the Rust crate's structurally (same setup arrays, same
blocks, same maps). Goldens are emitted by ``loms netgen --golden
tests/golden`` (and `make goldens`); a Rust test regenerates and
compares them too, so drift on either side is caught."""

import json
import pathlib

import pytest

from compile.netgen import batcher, loms, s2ms

GOLDEN = pathlib.Path(__file__).resolve().parents[2] / "tests" / "golden"

CASES = {
    "loms2_up8_dn8_2col": lambda: loms.loms_2way(8, 8, 2),
    "loms2_up7_dn5_2col": lambda: loms.loms_2way(7, 5, 2),
    "loms2_up32_dn32_8col": lambda: loms.loms_2way(32, 32, 8),
    "loms3_7r": lambda: loms.loms_kway([7, 7, 7]),
    "oem_up8_dn8": lambda: batcher.odd_even_merge(8),
    "bims_up8_dn8": lambda: batcher.bitonic_merge(8),
    "s2ms_up7_dn5": lambda: s2ms.s2ms(7, 5),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_matches_rust_golden(case):
    path = GOLDEN / f"{case}.json"
    if not path.exists():
        pytest.skip(f"golden {path} not generated (run `make goldens`)")
    rust = json.loads(path.read_text())
    py = CASES[case]().to_json()
    assert py["list_sizes"] == rust["list_sizes"], case
    assert py["input_map"] == rust["input_map"], case
    assert py["output_perm"] == rust["output_perm"], case
    assert py.get("median_tap") == rust.get("median_tap"), case
    assert py.get("grid") == rust.get("grid"), case
    assert len(py["stages"]) == len(rust["stages"]), case
    for ps, rs in zip(py["stages"], rust["stages"]):
        assert ps["blocks"] == rs["blocks"], f"{case}: stage {ps['label']}"
